"""Expression-DAG query compiler (repro.core.expr + the query/serve surface).

Bit-identity contract: lowering an expression shares ONE stage
reconstruction per distinct leaf, and every root's value equals composing
the corresponding single-op results (``oplib.compute``) with the same
pointwise arithmetic at the same stage — exactly, not approximately
(IEEE adds/subs/scales of identical inputs are deterministic).  Oracles
with closed forms (rigid rotation, quadratic ensembles) additionally pin
the absolute values.
"""
import warnings

import numpy as np
import pytest
import jax.numpy as jnp

try:
    from hypothesis import given, strategies as st
except ImportError:  # optional dep: property-based tests self-skip
    from repro.testing import given, st

from repro.core import Stage, expr, hszp, hszp_nd, hszx, hszx_nd, oplib
from repro.analytics import ExprPlan, plan_expr, query
from repro.analytics.engine import BatchedAnalytics
from repro.analytics.query import _query_opset
from repro.store import FieldStore

ALL = [hszp, hszx, hszp_nd, hszx_nd]
ND = [hszp_nd, hszx_nd]

N0, N1 = 48, 64
REGION = (slice(8, 40), slice(16, 48))


def _grid_2d():
    i = np.arange(N0, dtype=np.float32)[:, None]
    j = np.arange(N1, dtype=np.float32)[None, :]
    return i, j


def _compress(comp, data):
    # abs_eb=0.25 => q = 2*d exactly for integer-valued fields
    return comp.compress(jnp.asarray(data, jnp.float32), abs_eb=0.25)


def _stages(comp):
    return [Stage.Q, Stage.F] + ([Stage.P] if comp.scheme.is_nd else [])


def _op(c, name, stage, *, axis=0, region=None):
    return np.asarray(oplib.compute(c, name, stage, axis=axis,
                                    region=region)[name])


# ===========================================================================
# closed-form oracles
# ===========================================================================

@pytest.mark.parametrize("comp", ALL, ids=lambda c: c.scheme.value)
def test_vorticity_rigid_rotation_exact(comp):
    """vorticity = dv/dx - du/dy of (u, v) = (-y, x) is exactly +2, and the
    expression is bit-identical to composing the single-op results."""
    i, j = _grid_2d()
    cu = _compress(comp, -(j + np.zeros((N0, N1), np.float32)))
    cv = _compress(comp, i + np.zeros((N0, N1), np.float32))
    vort = expr.sub(expr.derivative(cv, axis=0), expr.derivative(cu, axis=1))
    for stage in _stages(comp):
        got = np.asarray(oplib.compute_exprs(vort, stage))
        oracle = (_op(cv, "derivative", stage, axis=0)
                  - _op(cu, "derivative", stage, axis=1))
        np.testing.assert_array_equal(got, oracle)
        np.testing.assert_allclose(
            got, np.full((N0 - 2, N1 - 2), 2.0, np.float32),
            rtol=1e-5, atol=1e-3)


@pytest.mark.parametrize("comp", ALL, ids=lambda c: c.scheme.value)
def test_ensemble_delta_quadratics_exact(comp):
    """laplacian(2(i²+j²)) - laplacian(i²+j²) is exactly 8 - 4 = 4."""
    i, j = _grid_2d()
    f = i * i + j * j
    c1 = _compress(comp, 2.0 * f)
    c2 = _compress(comp, f)
    delta = expr.laplacian(c1) - expr.laplacian(c2)
    for stage in _stages(comp):
        got = np.asarray(oplib.compute_exprs(delta, stage))
        oracle = _op(c1, "laplacian", stage) - _op(c2, "laplacian", stage)
        np.testing.assert_array_equal(got, oracle)
        np.testing.assert_allclose(
            got, np.full((N0 - 2, N1 - 2), 4.0, np.float32),
            rtol=1e-5, atol=1e-3)


# ===========================================================================
# expression == op-compose, all schemes, ± region, ± store seeding
# ===========================================================================

@pytest.mark.parametrize("comp", ALL, ids=lambda c: c.scheme.value)
@pytest.mark.parametrize("region", [None, REGION],
                         ids=["full", "region"])
def test_expression_matches_compose(comp, region, field_2d):
    """A mixed DAG (stencil + scaled statistics, shared leaf) equals the
    composed single-op results bit-for-bit at every feasible stage."""
    c1 = comp.compress(jnp.asarray(field_2d[:N0, :N1]), rel_eb=1e-3)
    c2 = comp.compress(jnp.asarray(field_2d[50:50 + N0, 20:20 + N1]),
                       rel_eb=1e-3)
    e = (expr.laplacian(c1) + 0.5 * expr.mean(c2)) - expr.std(c1)
    for stage in _stages(comp):
        got = np.asarray(oplib.compute_exprs(e, stage, region=region))
        oracle = (_op(c1, "laplacian", stage, region=region)
                  + 0.5 * _op(c2, "mean", stage, region=region)
                  - _op(c1, "std", stage, region=region))
        np.testing.assert_array_equal(got, oracle)


@pytest.mark.parametrize("comp", ND, ids=lambda c: c.scheme.value)
@pytest.mark.parametrize("region", [None, REGION], ids=["full", "region"])
def test_store_seeded_expression_bit_identical(comp, region, field_2d):
    """Store-backed id leaves: the warm (seeded) run returns bit-identical
    values to the cold run, and planning sees the residency."""
    store = FieldStore(cache_bytes=1 << 30)
    store.put("u", comp.compress(jnp.asarray(field_2d[:N0, :N1]),
                                 rel_eb=1e-3))
    store.put("v", comp.compress(jnp.asarray(field_2d[40:40 + N0, 10:10 + N1]),
                                 rel_eb=1e-3))
    vort = expr.sub(expr.derivative("v", axis=0), expr.derivative("u", axis=1))
    engine = BatchedAnalytics()
    cold = query(exprs=[vort], store=store, region=region, engine=engine)
    warm = query(exprs=[vort], store=store, region=region, engine=engine)
    np.testing.assert_array_equal(np.asarray(cold.values[0]),
                                  np.asarray(warm.values[0]))
    assert warm.store_hits >= 2 and warm.store_misses == 0
    assert store.is_resident("u", cold.stages[0], region=region,
                             closure=expr.leaf_closure(
                                 expr.analyze([vort]), 1,
                                 comp.scheme, cold.stages[0]))
    # and both agree with the storeless (eager) lowering at the planned
    # stage — allclose, not equal: XLA fuses the jitted program differently
    # from the eager trace (the seeded/unseeded runs above ARE bit-equal)
    ref = oplib.compute_exprs(
        expr.sub(expr.derivative(store.get("v"), axis=0),
                 expr.derivative(store.get("u"), axis=1)),
        cold.stages[0], region=region)
    np.testing.assert_allclose(np.asarray(cold.values[0]), np.asarray(ref),
                               rtol=1e-5, atol=1e-6)


# ===========================================================================
# shared prelude: exactly one StageContext (stage reconstruction) per leaf
# ===========================================================================

def test_shared_prelude_one_context_per_leaf(monkeypatch, field_2d):
    """Five consumers over two leaves build exactly two StageContexts, and
    the whole DAG is one compiled dispatch."""
    c1 = hszp_nd.compress(jnp.asarray(field_2d[:N0, :N1]), rel_eb=1e-3)
    c2 = hszp_nd.compress(jnp.asarray(field_2d[60:60 + N0, 5:5 + N1]),
                          rel_eb=1e-3)
    built = []
    real = oplib.StageContext

    class Counting(real):
        def __init__(self, *a, **k):
            built.append(1)
            super().__init__(*a, **k)

    monkeypatch.setattr(oplib, "StageContext", Counting)
    e1 = expr.laplacian(c1) - expr.scale(expr.mean(c1), 2.0)
    e2 = expr.std(c1) + expr.laplacian(c2)
    out = oplib.compute_exprs([e1, e2], Stage.Q)
    assert len(built) == 2  # two distinct leaves, five op applications
    oracle = oplib.compute(c1, ["laplacian", "mean", "std"], Stage.Q)
    oracle2 = oplib.compute(c2, "laplacian", Stage.Q)
    np.testing.assert_array_equal(
        np.asarray(out[0]),
        np.asarray(oracle["laplacian"]) - 2.0 * np.asarray(oracle["mean"]))
    np.testing.assert_array_equal(
        np.asarray(out[1]),
        np.asarray(oracle["std"]) + np.asarray(oracle2["laplacian"]))


def test_query_expression_single_dispatch(field_2d):
    """query(exprs=[...]) compiles and issues exactly one program for a
    multi-root spatial DAG, and reuses it on re-query."""
    c1 = hszp_nd.compress(jnp.asarray(field_2d[:N0, :N1]), rel_eb=1e-3)
    c2 = hszp_nd.compress(jnp.asarray(field_2d[30:30 + N0, 8:8 + N1]),
                          rel_eb=1e-3)
    engine = BatchedAnalytics()
    roots = [expr.laplacian(c1) - expr.laplacian(c2),
             expr.mean(c1) + expr.mean(c2)]
    res = query(exprs=roots, engine=engine)
    assert res.n_dispatches == 1 and res.n_batches == 1
    assert engine.cache_size == 1
    again = query(exprs=roots, engine=engine)
    assert engine.cache_size == 1  # same canonical program: cache hit
    for a, b in zip(res.values, again.values):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ===========================================================================
# canonicalization: CSE, commuted adds, structural keys
# ===========================================================================

def test_cse_one_postlude_per_distinct_application(field_2d):
    c = hszp_nd.compress(jnp.asarray(field_2d[:N0, :N1]), rel_eb=1e-3)
    e = expr.laplacian(c) + expr.laplacian(c)
    program = expr.analyze([e])
    assert len(program.leaves) == 1
    assert len(program.op_nodes) == 1  # identical applications deduplicate
    got = np.asarray(oplib.compute_exprs(e, Stage.Q))
    np.testing.assert_array_equal(got, 2.0 * _op(c, "laplacian", Stage.Q))


def test_add_commutes_into_one_program_key(field_2d):
    c1 = hszp_nd.compress(jnp.asarray(field_2d[:N0, :N1]), rel_eb=1e-3)
    c2 = hszp_nd.compress(jnp.asarray(field_2d[10:10 + N0, 4:4 + N1]),
                          rel_eb=1e-3)
    ab = expr.analyze([expr.add(expr.mean(c1), expr.std(c2))])
    ba = expr.analyze([expr.add(expr.std(c2), expr.mean(c1))])
    assert ab.key == ba.key  # IEEE add commutes bitwise: share the program
    s_ab = expr.analyze([expr.sub(expr.mean(c1), expr.std(c2))])
    s_ba = expr.analyze([expr.sub(expr.std(c2), expr.mean(c1))])
    assert s_ab.key != s_ba.key  # sub does not


# ===========================================================================
# joint DAG planning
# ===========================================================================

def test_plan_expr_joint_intersection(field_2d):
    """A component joining a stencil (②③④ on nd) with a mean picks one
    stage feasible for both; independent components plan independently."""
    nd = hszp_nd.compress(jnp.asarray(field_2d[:N0, :N1]), rel_eb=1e-3)
    flat = hszp.compress(jnp.asarray(field_2d[:N0, :N1]), rel_eb=1e-3)
    joined = expr.laplacian(nd) + expr.mean(nd)
    alone = expr.mean(flat)
    program = expr.analyze([joined, expr.add(alone, alone)])
    plan = plan_expr(program, [nd, flat])
    assert isinstance(plan, ExprPlan) and len(plan.stages) == 2
    s_joined = plan.stages[program.root_component[0]]
    assert s_joined in (Stage.P, Stage.Q, Stage.F)  # never ① (stencil)
    # the 1-D-partitioned scheme forbids stage ② stencils — but a lone mean
    # may run anywhere; explicit infeasible stages still raise end-to-end
    with pytest.raises(Exception, match="stencil|stage"):
        oplib.compute_exprs(expr.laplacian(flat), Stage.P)


def test_plan_expr_explicit_stage_validates(field_2d):
    c = hszp.compress(jnp.asarray(field_2d[:N0, :N1]), rel_eb=1e-3)
    program = expr.analyze([expr.laplacian(c) + expr.mean(c)])
    with pytest.raises(Exception):
        plan_expr(program, [c], stage=Stage.P)  # flat scheme: no ② stencils
    plan = plan_expr(program, [c], stage=Stage.Q)
    assert plan.stages == (Stage.Q,)


# ===========================================================================
# validation errors
# ===========================================================================

def test_bare_leaf_root_rejected(field_2d):
    c = hszp_nd.compress(jnp.asarray(field_2d[:N0, :N1]), rel_eb=1e-3)
    with pytest.raises(TypeError, match="bare leaf"):
        expr.analyze([expr.leaf(c)])


def test_op_on_op_rejected(field_2d):
    c = hszp_nd.compress(jnp.asarray(field_2d[:N0, :N1]), rel_eb=1e-3)
    with pytest.raises(TypeError, match="add/sub/scale"):
        expr.op("mean", expr.laplacian(c))


def test_cycle_detected(field_2d):
    c = hszp_nd.compress(jnp.asarray(field_2d[:N0, :N1]), rel_eb=1e-3)
    a = expr.mean(c) + expr.std(c)
    b = expr.scale(a, 2.0)
    object.__setattr__(a, "a", b)  # forge a cycle past immutability
    with pytest.raises(ValueError, match="cycle"):
        expr.analyze([b])


def test_duplicate_bundle_ids_rejected():
    with pytest.raises(ValueError, match="duplicate"):
        expr.divergence(("u", "u"))


def test_mixed_temporal_spatial_consumers_rejected():
    with pytest.raises(TypeError, match="temporal"):
        expr.analyze([expr.add(expr.tmean("s"), expr.mean("s"))])


def test_unknown_op_and_bad_scale(field_2d):
    c = hszp_nd.compress(jnp.asarray(field_2d[:N0, :N1]), rel_eb=1e-3)
    with pytest.raises(ValueError, match="unknown"):
        expr.op("median", c)
    with pytest.raises(TypeError):
        expr.scale(expr.mean(c), True)


def test_shape_mismatch_rejected(field_2d):
    c1 = hszp_nd.compress(jnp.asarray(field_2d[:N0, :N1]), rel_eb=1e-3)
    c2 = hszp_nd.compress(jnp.asarray(field_2d[:32, :32]), rel_eb=1e-3)
    e = expr.laplacian(c1) + expr.laplacian(c2)
    with pytest.raises(ValueError, match="shapes"):
        oplib.compute_exprs(e, Stage.Q)


# ===========================================================================
# registry hygiene (satellite): collision guard + arity-naming errors
# ===========================================================================

def test_register_op_collision_guard():
    spec = oplib.OpSpec("mean", "field", "statistic",
                        lambda s: (Stage.Q, Stage.F))
    with pytest.raises(ValueError, match="collision.*mean"):
        oplib.register_op(spec)


def test_mixed_arity_error_names_offenders():
    with pytest.raises(ValueError) as ei:
        oplib.canonical_ops(["mean", "tdelta"])
    msg = str(ei.value)
    assert "different arities" in msg
    assert "mean (field)" in msg and "tdelta (temporal)" in msg


# ===========================================================================
# deprecation shims (satellite): old spellings warn, stay bit-identical
# ===========================================================================

def test_query_op_spelling_deprecated_but_identical(field_2d):
    c = hszp_nd.compress(jnp.asarray(field_2d[:N0, :N1]), rel_eb=1e-3)
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        old = query([c], "mean")
    assert any(issubclass(x.category, DeprecationWarning) for x in w)
    ref = _query_opset([c], "mean")
    np.testing.assert_array_equal(np.asarray(old.values[0]),
                                  np.asarray(ref.values[0]))
    assert (old.n_batches, old.n_dispatches) == (ref.n_batches,
                                                 ref.n_dispatches)
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        alias = query([c], ops=["mean", "std"])
    assert any(issubclass(x.category, DeprecationWarning) for x in w)
    ref2 = _query_opset([c], ["mean", "std"])
    np.testing.assert_array_equal(np.asarray(alias.values[0]["std"]),
                                  np.asarray(ref2.values[0]["std"]))
    with pytest.raises(TypeError, match="op= or ops="):
        query([c], "mean", ops=["std"])
    with pytest.raises(TypeError, match="expression form"):
        query([c], exprs=[expr.mean(c)])


def test_serve_opset_form_deprecated(field_2d):
    from repro.serve.analytics import AnalyticsFrontend, AnalyticsRequest

    c = hszp_nd.compress(jnp.asarray(field_2d[:N0, :N1]), rel_eb=1e-3)
    fe = AnalyticsFrontend()
    fe.add_request(AnalyticsRequest(uid=0, fields=c, op=["mean", "std"]))
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        done = fe.run_until_drained()
    assert any(issubclass(x.category, DeprecationWarning) for x in w)
    assert done[0].error is None and set(done[0].result) == {"mean", "std"}


def test_serve_expression_requests(field_2d):
    from repro.serve.analytics import AnalyticsFrontend, AnalyticsRequest

    c1 = hszp_nd.compress(jnp.asarray(field_2d[:N0, :N1]), rel_eb=1e-3)
    c2 = hszp_nd.compress(jnp.asarray(field_2d[20:20 + N0, 6:6 + N1]),
                          rel_eb=1e-3)
    fe = AnalyticsFrontend()
    good = AnalyticsRequest(uid=0,
                            exprs=expr.laplacian(c1) - expr.laplacian(c2))
    multi = AnalyticsRequest(uid=1, exprs=[expr.mean(c1), expr.std(c2)])
    bad = AnalyticsRequest(uid=2, exprs=expr.leaf(c1))  # bare leaf
    for r in (good, multi, bad):
        fe.add_request(r)
    fe.run_until_drained()
    assert bad.error is not None and "leaf" in bad.error
    assert good.error is None and multi.error is None
    np.testing.assert_allclose(
        np.asarray(good.result),
        _op(c1, "laplacian", good.result_stage)
        - _op(c2, "laplacian", good.result_stage), rtol=1e-5, atol=1e-6)
    assert len(multi.result) == 2 and len(multi.result_stage) == 2


# ===========================================================================
# temporal expressions + counter parity (satellite)
# ===========================================================================

def _stream(comp, rng, slabs=3, k=4, n=24):
    from repro.stream import TemporalField

    tf = TemporalField(comp, abs_eb=0.01)
    for _ in range(slabs):
        tf.append(rng.random((k, n, n)).astype(np.float32))
    return tf


def test_temporal_expression_matches_flat():
    from repro.stream.query import query_temporal

    rng = np.random.default_rng(7)
    tf = _stream(hszp_nd, rng)
    e = expr.tmean(tf) - expr.tdelta(tf)
    res = query(exprs=[e])
    flat = query_temporal([tf], ["tmean", "tdelta"])
    np.testing.assert_array_equal(
        np.asarray(res.values[0]),
        np.asarray(flat.values[0]["tmean"])
        - np.asarray(flat.values[0]["tdelta"]))
    # one summary per stream slot even with two consumers
    assert res.n_dispatches >= 2


def test_temporal_counters_uniform_with_spatial():
    """query_temporal reports dispatch/batch accounting like the spatial
    path: n_dispatches counts compiled calls (summaries, merges,
    postludes), n_batches counts layout groups."""
    from repro.stream.query import query_temporal

    rng = np.random.default_rng(8)
    t1 = _stream(hszp_nd, rng)
    t2 = _stream(hszp_nd, rng)  # same layout: one batch group
    res = query_temporal([t1, t2], "tmean")
    assert res.n_batches == 1
    # per stream: 1 batched summarize + 2 merges + 1 postlude = 4
    assert res.n_dispatches == 8
    assert res.store_hits == 0 and res.store_misses == 0
    t3 = _stream(hszx_nd, rng)  # different scheme: second layout group
    res2 = query_temporal([t1, t3], "tmean")
    assert res2.n_batches == 2


def test_cross_stream_delta_store_backed():
    from repro.stream import StreamFieldStore, TemporalField
    from repro.stream.query import query_temporal

    rng = np.random.default_rng(9)
    store = StreamFieldStore(cache_bytes=1 << 30)
    for fid in ("a", "b"):
        store.put_temporal(fid, TemporalField(hszp_nd, abs_eb=0.01))
        for _ in range(3):
            store.append(fid, rng.random((4, 24, 24)).astype(np.float32))
    res = query(exprs=[expr.sub(expr.tmean("a"), expr.tmean("b"))],
                store=store)
    a = np.asarray(query_temporal(["a"], "tmean", store=store).values[0])
    b = np.asarray(query_temporal(["b"], "tmean", store=store).values[0])
    np.testing.assert_array_equal(np.asarray(res.values[0]), a - b)


# ===========================================================================
# property test: random small DAGs == composed single-op oracle
# ===========================================================================

_leaf_ops = st.sampled_from(["mean", "std", "laplacian"])


@st.composite
def _dags(draw):
    """A random expression tree over up to 3 leaves (by index) with up to
    depth-3 combinators; returns a spec the test folds into an Expr."""
    n_leaves = draw(st.integers(1, 3))

    def node(depth):
        if depth >= 3 or draw(st.booleans()):
            return ("op", draw(_leaf_ops), draw(st.integers(0, n_leaves - 1)))
        kind = draw(st.sampled_from(["add", "sub", "scale"]))
        if kind == "scale":
            alpha = draw(st.sampled_from([-2.0, 0.5, 1.0, 3.0]))
            return ("scale", alpha, node(depth + 1))
        return (kind, node(depth + 1), node(depth + 1))

    return n_leaves, node(0)


@given(_dags())
def test_random_dag_matches_composed_oracle(spec, field_2d):
    n_leaves, tree = spec
    comps = [hszp_nd.compress(
        jnp.asarray(field_2d[o:o + 32, o:o + 32]), rel_eb=1e-3)
        for o in (0, 16, 48)][:n_leaves]

    def build(t):
        if t[0] == "op":
            return expr.op(t[1], comps[t[2]])
        if t[0] == "scale":
            return expr.scale(build(t[2]), t[1])
        return (expr.add if t[0] == "add" else expr.sub)(build(t[1]),
                                                         build(t[2]))

    def oracle(t):
        if t[0] == "op":
            return _op(comps[t[2]], t[1], Stage.Q)
        if t[0] == "scale":
            return oracle(t[2]) * np.float32(t[1])
        a, b = oracle(t[1]), oracle(t[2])
        return a + b if t[0] == "add" else a - b

    got = np.asarray(oplib.compute_exprs(build(tree), Stage.Q))
    np.testing.assert_allclose(got, oracle(tree), rtol=1e-5, atol=1e-5)
