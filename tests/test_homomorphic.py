"""Homomorphic operations vs stage-④ results, within paper §V-D bias bounds.

This is the reproduction of the paper's Table V: every operation at every
supported stage must match the full-decompression result within its proven
bound (eps for metadata/blockmean-std paths, float round-off otherwise).
"""
import numpy as np
import pytest
import jax.numpy as jnp

from repro.core import (Stage, UnsupportedStageError, error_analysis,
                        homomorphic as H, hszp, hszp_nd, hszx, hszx_nd)

ALL = [hszp, hszx, hszp_nd, hszx_nd]
ND = [hszp_nd, hszx_nd]


def _c(comp, data, rel_eb=1e-3):
    return comp.compress(jnp.asarray(data), rel_eb=rel_eb)


# -- statistics --------------------------------------------------------------

@pytest.mark.parametrize("comp", ALL, ids=lambda c: c.scheme.value)
@pytest.mark.parametrize("stage", [Stage.M, Stage.P, Stage.Q])
def test_mean(comp, stage, field_2d):
    c = _c(comp, field_2d)
    if stage == Stage.M and not comp.scheme.is_blockmean:
        with pytest.raises(UnsupportedStageError):
            H.mean(c, stage)
        return
    got = float(H.mean(c, stage))
    ref = float(H.mean(c, Stage.F))
    assert abs(got - ref) <= error_analysis.mean_bias_bound(c, stage) + 1e-6


@pytest.mark.parametrize("comp", ALL, ids=lambda c: c.scheme.value)
@pytest.mark.parametrize("stage", [Stage.P, Stage.Q])
def test_std(comp, stage, field_2d):
    c = _c(comp, field_2d)
    got = float(H.std(c, stage))
    ref = float(H.std(c, Stage.F))
    assert abs(got - ref) <= error_analysis.std_bias_bound(c, stage) + 1e-5


@pytest.mark.parametrize("comp", ND, ids=lambda c: c.scheme.value)
def test_stats_3d(comp, field_3d):
    c = _c(comp, field_3d)
    ref_mu, ref_sd = float(H.mean(c, Stage.F)), float(H.std(c, Stage.F))
    for stage in (Stage.P, Stage.Q):
        assert abs(float(H.mean(c, stage)) - ref_mu) <= \
            error_analysis.mean_bias_bound(c, stage) + 1e-6
        assert abs(float(H.std(c, stage)) - ref_sd) <= \
            error_analysis.std_bias_bound(c, stage) + 1e-5
    if comp.scheme.is_blockmean:
        assert abs(float(H.mean(c, Stage.M)) - ref_mu) <= float(c.eps)


def test_mean_metadata_padding():
    """Stage-① mean stays within eps when blocks are padded (non-divisible)."""
    rng = np.random.default_rng(5)
    d = rng.normal(3.0, 1.0, (37, 53)).astype(np.float32)  # forces padding
    c = hszx_nd.compress(jnp.asarray(d), rel_eb=1e-3)
    mu = float(H.mean(c, Stage.M))
    assert abs(mu - d.mean()) <= 2 * float(c.eps)


# -- numerical differentiation ------------------------------------------------

@pytest.mark.parametrize("comp", ALL, ids=lambda c: c.scheme.value)
@pytest.mark.parametrize("stage", [Stage.P, Stage.Q])
@pytest.mark.parametrize("op", ["derivative", "laplacian"])
def test_differentiation(comp, stage, op, field_2d):
    c = _c(comp, field_2d)
    if stage == Stage.P and not comp.scheme.is_nd:
        with pytest.raises(UnsupportedStageError):
            if op == "derivative":
                H.derivative(c, stage, 0)
            else:
                H.laplacian(c, stage)
        return
    if op == "derivative":
        got = np.asarray(H.derivative(c, stage, 0))
        ref = np.asarray(H.derivative(c, Stage.F, 0))
    else:
        got = np.asarray(H.laplacian(c, stage))
        ref = np.asarray(H.laplacian(c, Stage.F))
    # stage-②/③ stencils are exact integer arithmetic scaled once; the
    # reference applies the same stencil to d' = q*2eps -> equal to fp roundoff
    np.testing.assert_allclose(got, ref, rtol=1e-4, atol=float(c.eps) * 1e-3)


@pytest.mark.parametrize("comp", ND, ids=lambda c: c.scheme.value)
def test_derivative_matches_numpy_3d(comp, field_3d):
    """End-to-end vs a numpy central difference on the decompressed data."""
    c = _c(comp, field_3d)
    df = np.asarray(comp.decompress(c, Stage.F))
    for axis in range(3):
        got = np.asarray(H.derivative(c, Stage.P, axis))
        sl_hi = [slice(1, -1)] * 3
        sl_lo = [slice(1, -1)] * 3
        sl_hi[axis] = slice(2, None)
        sl_lo[axis] = slice(None, -2)
        ref = (df[tuple(sl_hi)] - df[tuple(sl_lo)]) * 0.5
        np.testing.assert_allclose(got, ref, rtol=2e-3, atol=float(c.eps))


# -- multivariate -------------------------------------------------------------

@pytest.mark.parametrize("comp", ND, ids=lambda c: c.scheme.value)
@pytest.mark.parametrize("stage", [Stage.P, Stage.Q])
def test_divergence_curl(comp, stage, vector_field_2d):
    u, v = vector_field_2d
    cu, cv = _c(comp, u), _c(comp, v)
    for op in (H.divergence, H.curl):
        got = np.asarray(op([cu, cv], stage))
        ref = np.asarray(op([cu, cv], Stage.F))
        np.testing.assert_allclose(got, ref, rtol=1e-4, atol=float(cu.eps) * 1e-3)


def test_curl_3d(field_3d):
    comps = [hszp_nd.compress(jnp.asarray(field_3d * s), rel_eb=1e-3)
             for s in (1.0, 0.7, 1.3)]
    got = H.curl(comps, Stage.Q)
    ref = H.curl(comps, Stage.F)
    for g, r in zip(got, ref):
        np.testing.assert_allclose(np.asarray(g), np.asarray(r),
                                   rtol=1e-4, atol=1e-5)


# -- paper Table V analogue ----------------------------------------------------

def test_max_relative_error_table(field_2d):
    """Paper Table V analogue: worst-stage mean error, normalized by the
    field's value range (the paper's fields have O(1) means; ours is
    near-zero, so |err|/|mean| would be meaningless)."""
    vrange = float(np.ptp(field_2d))
    for comp in ALL:
        c = _c(comp, field_2d, rel_eb=1e-3)
        stages = [Stage.P, Stage.Q] + ([Stage.M] if comp.scheme.is_blockmean else [])
        ref = float(H.mean(c, Stage.F))
        worst = max(abs(float(H.mean(c, s)) - ref) for s in stages)
        # stage-① bias bound is eps = 1e-3 * range (paper §V-D.1)
        assert worst / vrange < 1.1e-3, (comp.scheme, worst / vrange)
