"""Materialized-stage field store (ISSUE 4 acceptance).

The contract under test:

* store-backed query results are **bit-identical** to storeless queries for
  every (scheme, op-set, stage, ±region) cell — field-arity and vector-arity
  sets, Compressed and Encoded containers;
* ``stage="auto"`` provably flips to a cached stage when the cache-aware
  cost model says so — both uncalibrated (residency beats reconstruction)
  and calibrated (measured cost minus fig34 reconstruction term);
* the ``FieldStore`` is a byte-budgeted LRU with exact hit / miss /
  eviction accounting and id-invalidation rules;
* serve resolves string field ids end to end with one dispatch per group;
* ``CostModel.save``/``load`` JSON round-trips the full calibration state.
"""
import numpy as np
import pytest
import jax.numpy as jnp

from repro import analytics
from repro.analytics import BatchedAnalytics, CostModel, query
from repro.core import (Scheme, Stage, homomorphic as H, hszp, hszp_nd, hszx,
                        hszx_nd, oplib)
from repro.serve import AnalyticsFrontend, AnalyticsRequest
from repro.store import FieldStore, MaterializedStage, materialize

ALL = [hszp, hszx, hszp_nd, hszx_nd]
REGION = ((30, 75), (10, 52))  # unaligned window of the 181x97 field_2d

FUSED_SETS = [("mean",), ("mean", "std"), ("mean", "std", "laplacian"),
              ("std", "derivative"), ("mean", "gradient")]


def _c(comp, data, rel_eb=1e-3):
    return comp.compress(jnp.asarray(data), rel_eb=rel_eb)

def _compress_many(comp, n, shape=(64, 48), rel_eb=1e-3, seed=0):
    rng = np.random.default_rng(seed)
    return [comp.compress(jnp.asarray(rng.normal(0, 1, shape).astype(np.float32)),
                          rel_eb=rel_eb) for _ in range(n)]


def _shared_stages(scheme, ops):
    return [s for s in Stage if s != Stage.M
            if all(s in analytics.feasible_stages(scheme, op) for op in ops)]


def _assert_same(got, ref):
    if isinstance(ref, tuple):
        assert isinstance(got, tuple) and len(got) == len(ref)
        for g, r in zip(got, ref):
            np.testing.assert_array_equal(np.asarray(g), np.asarray(r))
    else:
        np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))


# -- materialized stages ------------------------------------------------------

@pytest.mark.parametrize("comp", ALL, ids=lambda c: c.scheme.value)
def test_materialized_stage_is_pytree(comp, field_2d):
    import jax
    e = comp.encode(_c(comp, field_2d))
    m = materialize(e, Stage.Q)
    leaves = jax.tree_util.tree_leaves(m)
    assert leaves and m.nbytes == sum(x.size * x.dtype.itemsize for x in leaves)
    m2 = jax.tree.map(lambda x: x, m)
    assert isinstance(m2, MaterializedStage) and m2.sig() == m.sig()
    # stacking (what the engine's seeded programs do) keeps the treedef
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), m, m2)
    assert stacked.q_spatial.shape == (2,) + m.q_spatial.shape


def test_materialize_rejects_stage_m(field_2d):
    e = hszx_nd.encode(_c(hszx_nd, field_2d))
    with pytest.raises(ValueError, match="already resident"):
        materialize(e, Stage.M)


def test_mismatched_seed_rejected(field_2d):
    e = hszp_nd.encode(_c(hszp_nd, field_2d))
    m_q = materialize(e, Stage.Q)
    with pytest.raises(ValueError, match="does not match"):
        H.compute(e, "mean", Stage.P, seed=m_q)
    m_reg = materialize(e, Stage.Q, region=REGION, closure="hull")
    with pytest.raises(ValueError, match="does not match"):
        H.compute(e, "mean", Stage.Q, seed=m_reg)  # region key mismatch


# -- bit-identical store-backed queries: every (scheme, op-set, stage, ±region)

@pytest.mark.parametrize("comp", ALL, ids=lambda c: c.scheme.value)
@pytest.mark.parametrize("ops", FUSED_SETS, ids="+".join)
def test_store_backed_bit_identical(comp, ops, field_2d):
    c = _c(comp, field_2d)
    e = comp.encode(c)
    for field in (c, e):
        store = FieldStore()
        store.put("f", field)
        eng = BatchedAnalytics()
        for stage in _shared_stages(comp.scheme, ops):
            for region in (None, REGION):
                ref = query([field], list(ops), stage=stage, engine=eng,
                            region=region)
                got = query(["f"], list(ops), stage=stage, engine=eng,
                            region=region, store=store)
                hot = query(["f"], list(ops), stage=stage, engine=eng,
                            region=region, store=store)
                # first call misses (unless stage ④ reuses the ③ entry),
                # second is always served resident
                assert got.store_misses + got.store_hits >= 1
                assert hot.store_misses == 0 and hot.store_hits >= 1
                for op in ops:
                    _assert_same(got.values[0][op], ref.values[0][op])
                    _assert_same(hot.values[0][op], ref.values[0][op])


@pytest.mark.parametrize("comp", [hszp_nd, hszx_nd], ids=lambda c: c.scheme.value)
@pytest.mark.parametrize("ops", [("divergence",), ("divergence", "curl")],
                         ids="+".join)
def test_store_backed_vector_bit_identical(comp, ops, vector_field_2d):
    u, v = vector_field_2d
    cu, cv = _c(comp, u), _c(comp, v)
    store = FieldStore()
    store.put("u", cu)
    store.put("v", cv)
    eng = BatchedAnalytics()
    region = ((20, 60), (40, 90))
    for stage in _shared_stages(comp.scheme, ops):
        for r in (None, region):
            ref = query([(cu, cv)], list(ops), stage=stage, engine=eng,
                        region=r)
            for _ in range(2):  # miss pass, then hit pass
                got = query([("u", "v")], list(ops), stage=stage, engine=eng,
                            region=r, store=store)
                for op in ops:
                    _assert_same(got.values[0][op], ref.values[0][op])
            assert got.store_hits >= 2  # both components served hot


def test_store_backed_batch_and_mixed_inputs(field_2d):
    """Ids and raw containers mix in one query; ids group separately (only
    they can seed) but every value matches the storeless reference."""
    cs = _compress_many(hszp_nd, 4)
    store = FieldStore()
    for i, c in enumerate(cs[:2]):
        store.put(f"f{i}", c)
    eng = BatchedAnalytics()
    ref = query(cs, ["mean", "std"], stage=Stage.P, engine=eng)
    got = query(["f0", "f1", cs[2], cs[3]], ["mean", "std"], stage=Stage.P,
                engine=eng, store=store)
    assert got.n_batches == 2  # store-backed vs raw split
    for i in range(4):
        for op in ("mean", "std"):
            _assert_same(got.values[i][op], ref.values[i][op])


def test_seeded_engine_program_is_separate_and_equal(field_2d):
    eng = BatchedAnalytics()
    cs = _compress_many(hszp_nd, 3)
    store = FieldStore()
    ids = [store.put(f"f{i}", c) for i, c in enumerate(cs)]
    cold = query(cs, "std", stage=Stage.Q, engine=eng)
    assert eng.cache_size == 1
    hot = query(ids, "std", stage=Stage.Q, engine=eng, store=store)
    assert eng.cache_size == 2  # seeded program compiles separately
    for a, b in zip(cold.values, hot.values):
        _assert_same(b, a)
    query(ids, "std", stage=Stage.Q, engine=eng, store=store)
    assert eng.cache_size == 2  # and is reused on the hit path


def test_stage_f_std_accurate_for_mean_dominated_fields():
    """The stage-④ std (the accuracy reference) must be mean-subtracted: a
    single-pass moments form catastrophically cancels in f32 when the mean
    dominates the spread (1000 ± 0.1 -> garbage)."""
    rng = np.random.default_rng(3)
    d = (1000.0 + rng.normal(0, 0.1, (128, 128))).astype(np.float32)
    c = hszx_nd.compress(jnp.asarray(d), rel_eb=1e-4)
    got = float(H.std(c, Stage.F))
    assert abs(got - d.std(ddof=1)) < 1e-3, (got, d.std(ddof=1))


def test_vector_op_bare_string_id_rejected(field_2d):
    store = FieldStore()
    store.put("uv", _c(hszp_nd, field_2d))
    with pytest.raises(TypeError, match="per component"):
        query(["uv"], "curl", stage=Stage.Q, store=store)


# -- cache-aware auto planning ------------------------------------------------

def test_auto_flips_to_cached_stage_uncalibrated(field_2d):
    """Residency alone flips the plan: Lorenzo {mean, std} auto-plans ② cold,
    but a resident stage-③ materialization beats any reconstruction."""
    c = _c(hszp_nd, field_2d)
    store = FieldStore()
    store.put("f", c)
    eng = BatchedAnalytics()
    cold = query([c], ["mean", "std"], engine=eng)
    assert cold.stages[0] == {"mean": Stage.P, "std": Stage.P}
    store.ensure("f", Stage.Q)
    hot = query(["f"], ["mean", "std"], engine=eng, store=store)
    assert hot.stages[0] == {"mean": Stage.Q, "std": Stage.Q}
    assert hot.store_hits >= 1
    ref = query([c], ["mean", "std"], stage=Stage.Q, engine=eng)
    for op in ("mean", "std"):
        _assert_same(hot.values[0][op], ref.values[0][op])


def test_auto_flip_calibrated_reconstruction_term():
    """With measured costs, a cached stage is priced at cost minus the fig34
    reconstruction term — which flips the choice exactly when that term is
    what made the higher stage lose."""
    scheme = Scheme.HSZP_ND
    cm = CostModel()
    for op in ("mean", "std"):
        cm.record(scheme, op, Stage.P, 100.0)
        cm.record(scheme, op, Stage.Q, 120.0)
        cm.record(scheme, op, Stage.F, 500.0)
    cm.record_reconstruction(scheme, Stage.Q, 110.0)
    # cold: P wins (200 < 240)
    assert analytics.plan_stages(scheme, ["mean", "std"],
                                 cost_model=cm).fused == Stage.P
    # Q resident: 2 * (120 - 110) = 20 < 200 -> flips to Q
    plan = analytics.plan_stages(scheme, ["mean", "std"], cost_model=cm,
                                 cached=frozenset({Stage.Q}))
    assert plan.fused == Stage.Q
    # a cached stage never goes below zero cost, and stage order breaks ties
    cm.record_reconstruction(scheme, Stage.P, 500.0)
    assert cm.cost(scheme, "mean", Stage.P, cached=True) == 0.0


def test_unmeasured_reconstruction_discount_is_conservative():
    """A cached stage with no measured reconstruction must not undercut a
    measured rival on made-up numbers: the fallback discount is the largest
    reconstruction measured at a *lower* stage (monotone in stage), so a
    stage-③ entry that also serves stage ④ still routes the plan to ③."""
    scheme = Scheme.HSZP_ND
    cm = CostModel()
    for op, p, q, f in (("mean", 90.0, 130.0, 700.0),
                        ("std", 95.0, 140.0, 700.0)):
        cm.record(scheme, op, Stage.P, p)
        cm.record(scheme, op, Stage.Q, q)
        cm.record(scheme, op, Stage.F, f)
    cm.record_reconstruction(scheme, Stage.Q, 80.0)
    # Q entry resident => both Q and F count as cached; F's reconstruction
    # is unmeasured and discounts by recon(Q)=80, keeping F at 1240 vs 110
    plan = analytics.plan_stages(scheme, ["mean", "std"], cost_model=cm,
                                 cached=frozenset({Stage.Q, Stage.F}))
    assert plan.fused == Stage.Q
    assert cm.cost(scheme, "mean", Stage.F, cached=True) == 620.0
    # with nothing measured at a lower stage there is no discount at all
    cm2 = CostModel()
    cm2.record(scheme, "mean", Stage.P, 90.0)
    cm2.record(scheme, "mean", Stage.Q, 130.0)
    assert cm2.cost(scheme, "mean", Stage.Q, cached=True) == 130.0


def test_plan_stage_cached_preference_keeps_metadata_fast_path():
    """Stage ① needs no reconstruction (metadata is resident in the
    container), so a cached higher stage must not displace it."""
    assert analytics.plan_stage(Scheme.HSZX_ND, "mean",
                                cached=frozenset({Stage.Q})) == Stage.M
    assert analytics.plan_stage(Scheme.HSZP_ND, "mean",
                                cached=frozenset({Stage.Q})) == Stage.Q


def test_cached_stages_requires_matching_region_and_closure(field_2d):
    c = _c(hszp_nd, field_2d)
    store = FieldStore()
    store.put("f", c)
    store.ensure("f", Stage.Q)
    # the stage-③ integers serve stage ④ too (dequantize is postlude)
    assert store.cached_stages("f", ["mean", "std"]) == {Stage.Q, Stage.F}
    # the full-field entry does not serve a region query (different key) ...
    assert store.cached_stages("f", ["mean", "std"], region=REGION) == frozenset()
    cl = oplib.set_closure(["mean", "std"], c.scheme, Stage.Q)
    store.ensure("f", Stage.Q, region=REGION, closure=cl)
    assert store.cached_stages("f", ["mean", "std"],
                               region=REGION) == {Stage.Q, Stage.F}
    # ... and closures are part of the key: a stage-② derivative band entry
    # is not the hull the {mean, std} set needs
    band = oplib.set_closure("derivative", c.scheme, Stage.P, axis=0)
    hull = oplib.set_closure(["mean", "std"], c.scheme, Stage.P)
    assert band != hull
    store.ensure("f", Stage.P, region=REGION, closure=band)
    assert Stage.P not in store.cached_stages("f", ["mean", "std"],
                                              region=REGION)
    assert Stage.P in store.cached_stages("f", "derivative", region=REGION)


# -- FieldStore semantics -----------------------------------------------------

def test_field_registry_semantics(field_2d):
    c = _c(hszx_nd, field_2d)
    store = FieldStore()
    store.put("a", c)
    assert "a" in store and store.get("a") is c and store.ids() == ("a",)
    with pytest.raises(ValueError, match="already registered"):
        store.put("a", c)
    with pytest.raises(KeyError, match="unknown field id"):
        store.get("missing")
    with pytest.raises(TypeError):
        store.put("b", np.zeros(4))
    with pytest.raises(ValueError):
        store.put("", c)


def test_replace_and_remove_invalidate_materializations(field_2d):
    c1 = _c(hszx_nd, field_2d)
    c2 = _c(hszx_nd, field_2d * 2.0)
    store = FieldStore()
    store.put("a", c1)
    store.ensure("a", Stage.Q)
    assert store.cache_entries == 1
    store.put("a", c2, replace=True)
    assert store.cache_entries == 0  # stale intermediate dropped
    assert store.stats.evictions == 1  # invalidation counts as churn
    m = store.ensure("a", Stage.Q)
    _assert_same(m.q_spatial, materialize(c2, Stage.Q).q_spatial)
    store.remove("a")
    assert "a" not in store and store.cache_entries == 0
    assert store.cache_bytes_in_use == 0


def test_lru_eviction_under_byte_budget(field_2d):
    c = _c(hszx_nd, field_2d)
    one = materialize(c, Stage.Q).nbytes
    store = FieldStore(cache_bytes=int(2.5 * one))
    for i in range(3):
        store.put(f"f{i}", c)
    store.ensure("f0", Stage.Q)
    store.ensure("f1", Stage.Q)
    assert store.cache_entries == 2
    store.lookup("f0", Stage.Q)          # refresh f0 -> f1 becomes LRU
    store.ensure("f2", Stage.Q)          # budget forces one eviction
    assert store.cache_entries == 2
    assert store.stats.evictions == 1
    assert store.cache_bytes_in_use <= store.cache_bytes
    assert store.lookup("f0", Stage.Q) is not None   # survivor
    assert store.lookup("f1", Stage.Q) is None       # evicted (miss)
    assert (store.stats.hits, store.stats.misses) == (2, 4)


def test_oversized_entry_not_retained(field_2d):
    c = _c(hszx_nd, field_2d)
    store = FieldStore(cache_bytes=16)   # smaller than any materialization
    store.put("a", c)
    m = store.ensure("a", Stage.Q)       # still computed and returned ...
    assert m.q_spatial is not None
    assert store.cache_entries == 0      # ... but never resident
    # counted as a rejection, not an eviction (it was never resident)
    assert store.stats.rejected == 1 and store.stats.evictions == 0
    # seed() declines outright (no wasted reconstruction), so queries fall
    # back to unseeded execution instead of re-materializing every call
    assert store.seed("a", Stage.Q) is None
    assert store.stats.rejected == 2
    misses0 = store.stats.misses
    res = query(["a"], ["mean", "std"], stage=Stage.Q, store=store)
    ref = query([c], ["mean", "std"], stage=Stage.Q)
    for op in ("mean", "std"):
        _assert_same(res.values[0][op], ref.values[0][op])
    assert store.stats.misses == misses0  # never touched the cache again


@pytest.mark.parametrize("comp", ALL, ids=lambda c: c.scheme.value)
def test_materialized_nbytes_predicts_exactly(comp, field_2d):
    """The static size predictor must equal the realized nbytes — it is the
    retention decision, so drift would retain unboundedly or decline hot
    cells."""
    from repro.store import materialized_nbytes
    e = comp.encode(_c(comp, field_2d))
    for stage in (Stage.P, Stage.Q, Stage.F):
        for region, cl in ((None, "cover"),
                           (REGION, oplib.set_closure(["mean", "std"],
                                                      e.scheme, stage))):
            predicted = materialized_nbytes(e, stage, region=region,
                                            closure=cl)
            actual = materialize(e, stage, region=region, closure=cl).nbytes
            assert predicted == actual, (stage, region)


# -- serving by field id ------------------------------------------------------

def test_serve_resolves_field_ids_one_dispatch_per_group(field_2d):
    cs = _compress_many(hszx_nd, 3)
    store = FieldStore()
    for i, c in enumerate(cs):
        store.put(f"fields/{i}", c)
    fe = AnalyticsFrontend(store=store)
    for i in range(3):
        fe.add_request(AnalyticsRequest(uid=i, fields=f"fields/{i}",
                                        op=["mean", "std"]))
    done = {r.uid: r for r in fe.run_until_drained()}
    assert all(r.error is None for r in done.values())
    assert fe.engine.cache_size == 1     # the whole id group: one program
    stage = done[0].result_stage["mean"]
    import jax
    refs = {op: jax.jit(lambda f, o=op: getattr(H, o)(f, stage))
            for op in ("mean", "std")}
    for i in range(3):
        _assert_same(done[i].result["mean"], refs["mean"](cs[i]))
        _assert_same(done[i].result["std"], refs["std"](cs[i]))
    # second round is served from resident materializations
    h0 = store.stats.hits
    fe.add_request(AnalyticsRequest(uid=9, fields="fields/0", op=["mean", "std"]))
    done = fe.run_until_drained()
    assert done[0].error is None and store.stats.hits > h0


def test_serve_vector_ids_and_rejections(field_2d, vector_field_2d):
    u, v = vector_field_2d
    store = FieldStore()
    store.put("u", _c(hszp_nd, u))
    store.put("v", _c(hszp_nd, v))
    fe = AnalyticsFrontend(store=store)
    fe.add_request(AnalyticsRequest(uid=0, fields=("u", "v"), op="curl"))
    fe.add_request(AnalyticsRequest(uid=1, fields="ghost", op="mean"))
    done = {r.uid: r for r in fe.run_until_drained()}
    assert done[0].error is None
    import jax
    ref = jax.jit(lambda a, b: H.curl([a, b], done[0].result_stage))
    _assert_same(done[0].result, ref(store.get("u"), store.get("v")))
    assert done[1].error is not None and "ghost" in done[1].error


def test_serve_ids_without_store_rejected(field_2d):
    fe = AnalyticsFrontend()             # no store attached
    fe.add_request(AnalyticsRequest(uid=0, fields="some/id", op="mean"))
    (r,) = fe.run_until_drained()
    assert r.error is not None and "store" in r.error


# -- CostModel persistence ----------------------------------------------------

def test_cost_model_save_load_roundtrip(tmp_path):
    cm = CostModel()
    cm.record(Scheme.HSZP_ND, "mean", Stage.P, 100.0)
    cm.record(Scheme.HSZP_ND, "mean", Stage.P, 200.0)   # running mean: 150
    cm.record(Scheme.HSZX, "std", Stage.Q, 42.0)
    cm.record_reconstruction(Scheme.HSZP_ND, Stage.Q, 80.0)
    path = tmp_path / "cost.json"
    cm.save(path)
    loaded = CostModel.load(path)
    assert loaded.table == cm.table
    assert loaded.recon == cm.recon
    assert loaded._counts == cm._counts
    # counts round-trip => post-load observations continue the same mean
    loaded.record(Scheme.HSZP_ND, "mean", Stage.P, 300.0)
    cm.record(Scheme.HSZP_ND, "mean", Stage.P, 300.0)
    assert loaded.table == cm.table
    # and the loaded model plans identically
    assert analytics.plan_stages(
        Scheme.HSZP_ND, ["mean"], cost_model=loaded).fused == Stage.P


def test_cost_model_load_rejects_foreign_json(tmp_path):
    path = tmp_path / "bogus.json"
    path.write_text('{"format": "something-else", "cells": []}')
    with pytest.raises(ValueError, match="not a hsz-cost-model"):
        CostModel.load(path)


def test_cost_model_calibrates_reconstruction_from_fig34_rows():
    csv = "\n".join([
        "name,us_per_call,derived",
        "fig34/Ocean/hszp_nd-q,80.0,GBps=1.0",
        "fig34/NYX/hszp_nd-q,120.0,GBps=1.0",
        "fig34/Ocean/hszp_nd-f,500.0,GBps=1.0",
        "fig58/Ocean/mean/hszp_nd-q,130.0,GBps=1.0",
        "fig58/Ocean/mean/hszp_nd-p,90.0,GBps=1.0",
        "fig58/Ocean/mean/hszp_nd-f,700.0,GBps=1.0",
    ])
    cm = CostModel.from_benchmark_csv(csv)
    assert cm.reconstruction(Scheme.HSZP_ND, Stage.Q) == 100.0  # mean of 2
    assert cm.reconstruction(Scheme.HSZP_ND, Stage.M) == 0.0
    assert cm.cost(Scheme.HSZP_ND, "mean", Stage.Q) == 130.0
    assert cm.cost(Scheme.HSZP_ND, "mean", Stage.Q, cached=True) == 30.0
    # cold: P (90 < 130); Q resident: 30 < 90 -> flip
    stages = (Stage.P, Stage.Q, Stage.F)
    assert cm.cheapest(Scheme.HSZP_ND, "mean", stages) == Stage.P
    assert cm.cheapest(Scheme.HSZP_ND, "mean", stages,
                       cached={Stage.Q}) == Stage.Q


# -- byte-accounting audit (ISSUE 5 satellite) --------------------------------

def _bytes_consistent(store):
    assert store.cache_bytes_in_use == sum(
        m.nbytes for m in store._cache.values())
    assert store.cache_bytes_in_use <= max(store.cache_bytes, 0) or (
        store.cache_entries == 1)


def test_byte_accounting_replay_put_replace_evict(field_2d):
    """Replay put/replace/evict sequences; after every step the byte counter
    must equal the sum of resident nbytes (no double-subtraction on the
    replace-with-eviction path, no self-eviction of the fresh entry)."""
    c1 = _c(hszx_nd, field_2d)
    c2 = _c(hszx_nd, field_2d * 2.0)
    one = materialize(c1, Stage.Q).nbytes
    store = FieldStore(cache_bytes=int(2.2 * one))
    for i in range(3):
        store.put(f"f{i}", c1)
    store.ensure("f0", Stage.Q); _bytes_consistent(store)
    store.ensure("f1", Stage.Q); _bytes_consistent(store)
    store.ensure("f2", Stage.Q); _bytes_consistent(store)   # evicts f0
    assert store.stats.evictions == 1
    # replace under pressure: invalidate + re-materialize
    store.put("f1", c2, replace=True); _bytes_consistent(store)
    store.ensure("f1", Stage.Q); _bytes_consistent(store)
    # same-key replace (the streaming summary refresh path)
    m = materialize(c2, Stage.Q)
    key = next(iter(store._cache))
    store._insert(key, m); _bytes_consistent(store)
    assert store._cache[key] is m                     # replaced in place
    # the just-inserted entry is never its own victim even at a tight budget
    small = FieldStore(cache_bytes=one)
    small._insert(("a",), materialize(c1, Stage.Q)); _bytes_consistent(small)
    small._insert(("b",), materialize(c2, Stage.Q)); _bytes_consistent(small)
    assert list(k[0] for k in small._cache) == ["b"]  # a evicted, b resident


def test_oversized_replacement_drops_stale_entry(field_2d):
    """Replacing a resident cell with a value too large to retain must not
    leave the *stale* old value serving hits (fatal for streaming summaries,
    which are replaced on every append)."""
    c = _c(hszx_nd, field_2d)
    m_small = materialize(c, Stage.Q)
    store = FieldStore(cache_bytes=2 * m_small.nbytes)
    key = ("x", Stage.Q, None, "cover")
    store._insert(key, m_small)
    assert key in store._cache

    class Oversized:
        nbytes = 10 * m_small.nbytes

    store._insert(key, Oversized())
    assert key not in store._cache        # stale entry gone, nothing resident
    assert store.cache_bytes_in_use == 0
    assert store.stats.rejected == 1 and store.stats.evictions == 1
    _bytes_consistent(store)


# -- cached-stage planning: infeasible intersections (ISSUE 5 satellite) ------

@pytest.mark.parametrize("comp", ALL, ids=lambda c: c.scheme.value)
def test_plan_stages_cached_outside_feasible_intersection(comp, field_2d):
    """A resident stage-② materialization under a gradient-bearing op set:
    for 1-D schemes the cached stage is outside the set's feasible
    intersection — planning must fall back to the cold choice (not raise,
    not price the infeasible stage), and store-backed queries must still
    answer bit-identically."""
    scheme = comp.scheme
    cold = analytics.plan_stages(scheme, ["mean", "gradient"])
    plan = analytics.plan_stages(scheme, ["mean", "gradient"],
                                 cached=frozenset({Stage.P}))
    if Stage.P in analytics.feasible_stages(scheme, "gradient"):
        assert plan.fused == Stage.P      # nd: resident stage serves the set
    else:
        assert plan.fused == cold.fused   # 1-D: clean cold fallback
    # calibrated: the discount must only ever apply inside the intersection
    cm = CostModel()
    for op in ("mean", "gradient"):
        for s in analytics.feasible_stages(scheme, op):
            cm.record(scheme, op, s, 100.0 * int(s))
        cm.record_reconstruction(scheme, Stage.Q, 50.0)
    plan_cal = analytics.plan_stages(scheme, ["mean", "gradient"],
                                     cost_model=cm,
                                     cached=frozenset({Stage.P}))
    for op, s in plan_cal.stages:
        assert s in analytics.feasible_stages(scheme, op)
    # end to end through the store
    c = _c(comp, field_2d)
    store = FieldStore()
    store.put("f", c)
    store.ensure("f", Stage.P)
    eng = BatchedAnalytics()
    got = query(["f"], ["mean", "gradient"], store=store, engine=eng)
    ref = query([c], ["mean", "gradient"],
                stage={op: s for op, s in
                       zip(("mean", "gradient"),
                           (got.stages[0]["mean"], got.stages[0]["gradient"]))}
                if got.stages[0]["mean"] != got.stages[0]["gradient"]
                else got.stages[0]["mean"], engine=eng)
    _assert_same(got.values[0]["mean"], ref.values[0]["mean"])
    _assert_same(got.values[0]["gradient"], ref.values[0]["gradient"])


# -- CostModel.load: older / hand-stripped payloads (ISSUE 5 satellite) -------

def test_cost_model_load_tolerates_stripped_payload(tmp_path):
    import json
    cm = CostModel()
    cm.record(Scheme.HSZP_ND, "mean", Stage.P, 100.0)
    cm.record(Scheme.HSZX, "std", Stage.Q, 42.0)
    cm.record_reconstruction(Scheme.HSZP_ND, Stage.Q, 80.0)
    path = tmp_path / "cost.json"
    cm.save(path)
    data = json.loads(path.read_text())
    del data["recon"]                       # older version: no recon table
    for cell in data["cells"]:
        cell.pop("count", None)             # no observation counts
    data["cells"].append({"scheme": "hszp_nd", "op": "std"})  # stripped cell
    path.write_text(json.dumps(data))
    with pytest.warns(UserWarning, match="skipped 1 malformed"):
        loaded = CostModel.load(path)
    # intact cells round-trip; the stripped cell and recon fall back to the
    # uncalibrated path instead of KeyError
    assert loaded.table[(Scheme.HSZP_ND, "mean", Stage.P)] == 100.0
    assert loaded.table[(Scheme.HSZX, "std", Stage.Q)] == 42.0
    assert loaded.recon == {}
    assert loaded.cost(Scheme.HSZP_ND, "std", Stage.Q) is None
    assert loaded.reconstruction(Scheme.HSZP_ND, Stage.Q) is None
    # counts default to 1, so post-load recording still averages sanely
    loaded.record(Scheme.HSZP_ND, "mean", Stage.P, 300.0)
    assert loaded.table[(Scheme.HSZP_ND, "mean", Stage.P)] == 200.0
    # and planning with the degraded model works (uncalibrated fallback)
    assert analytics.plan_stages(Scheme.HSZP_ND, ["mean", "std"],
                                 cost_model=loaded).fused == Stage.P


# -- serve-by-id per-request isolation (ISSUE 5 satellite) --------------------

def test_serve_per_request_isolation_and_cache_hygiene(field_2d, vector_field_2d):
    """Malformed requests — duplicate component ids, empty op list, region
    out of bounds — reject individually with a structured error; healthy
    requests in the same batch are served, and nothing poisons the engine's
    jit cache (subsequent identical queries still answer)."""
    u, v = vector_field_2d
    store = FieldStore()
    store.put("u", _c(hszp_nd, u))
    store.put("v", _c(hszp_nd, v))
    store.put("f", _c(hszp_nd, field_2d))
    fe = AnalyticsFrontend(store=store)
    fe.add_request(AnalyticsRequest(uid=0, fields=("u", "u"), op="curl"))
    fe.add_request(AnalyticsRequest(uid=1, fields="f", op=[]))
    fe.add_request(AnalyticsRequest(uid=2, fields="f", op="mean",
                                    region=((0, 5000), (0, 10))))
    fe.add_request(AnalyticsRequest(uid=3, fields=("u", "v"), op="curl"))
    fe.add_request(AnalyticsRequest(uid=4, fields="f", op=["mean", "std"]))
    done = {r.uid: r for r in fe.run_until_drained()}
    assert "duplicate field ids" in done[0].error
    assert "empty op set" in done[1].error
    assert "out of bounds" in done[2].error
    assert done[3].error is None and done[4].error is None
    n = fe.engine.cache_size
    # the rejected shapes left no poisoned programs: replaying the healthy
    # requests compiles nothing new and answers identically
    fe.add_request(AnalyticsRequest(uid=5, fields=("u", "v"), op="curl"))
    fe.add_request(AnalyticsRequest(uid=6, fields="f", op=["mean", "std"]))
    done2 = {r.uid: r for r in fe.run_until_drained()}
    assert fe.engine.cache_size == n
    assert done2[5].error is None and done2[6].error is None
    _assert_same(done2[5].result, done[3].result)
    for op in ("mean", "std"):
        _assert_same(done2[6].result[op], done[4].result[op])


def test_query_rejects_duplicate_vector_ids_but_allows_raw_duplicates(field_2d):
    store = FieldStore()
    store.put("u", _c(hszp_nd, field_2d))
    with pytest.raises(ValueError, match="duplicate field ids"):
        query([("u", "u")], "curl", stage=Stage.Q, store=store)
    # raw containers carry no identity: physical duplication stays legal
    c = _c(hszp_nd, field_2d)
    res = query([(c, c)], "curl", stage=Stage.Q)
    assert np.isfinite(np.asarray(res.values[0])).all()
