"""Materialized-stage field store (ISSUE 4 acceptance).

The contract under test:

* store-backed query results are **bit-identical** to storeless queries for
  every (scheme, op-set, stage, ±region) cell — field-arity and vector-arity
  sets, Compressed and Encoded containers;
* ``stage="auto"`` provably flips to a cached stage when the cache-aware
  cost model says so — both uncalibrated (residency beats reconstruction)
  and calibrated (measured cost minus fig34 reconstruction term);
* the ``FieldStore`` is a byte-budgeted LRU with exact hit / miss /
  eviction accounting and id-invalidation rules;
* serve resolves string field ids end to end with one dispatch per group;
* ``CostModel.save``/``load`` JSON round-trips the full calibration state.
"""
import numpy as np
import pytest
import jax.numpy as jnp

from repro import analytics
from repro.analytics import BatchedAnalytics, CostModel, query
from repro.core import (Scheme, Stage, homomorphic as H, hszp, hszp_nd, hszx,
                        hszx_nd, oplib)
from repro.serve import AnalyticsFrontend, AnalyticsRequest
from repro.store import FieldStore, MaterializedStage, materialize

ALL = [hszp, hszx, hszp_nd, hszx_nd]
REGION = ((30, 75), (10, 52))  # unaligned window of the 181x97 field_2d

FUSED_SETS = [("mean",), ("mean", "std"), ("mean", "std", "laplacian"),
              ("std", "derivative"), ("mean", "gradient")]


def _c(comp, data, rel_eb=1e-3):
    return comp.compress(jnp.asarray(data), rel_eb=rel_eb)

def _compress_many(comp, n, shape=(64, 48), rel_eb=1e-3, seed=0):
    rng = np.random.default_rng(seed)
    return [comp.compress(jnp.asarray(rng.normal(0, 1, shape).astype(np.float32)),
                          rel_eb=rel_eb) for _ in range(n)]


def _shared_stages(scheme, ops):
    return [s for s in Stage if s != Stage.M
            if all(s in analytics.feasible_stages(scheme, op) for op in ops)]


def _assert_same(got, ref):
    if isinstance(ref, tuple):
        assert isinstance(got, tuple) and len(got) == len(ref)
        for g, r in zip(got, ref):
            np.testing.assert_array_equal(np.asarray(g), np.asarray(r))
    else:
        np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))


# -- materialized stages ------------------------------------------------------

@pytest.mark.parametrize("comp", ALL, ids=lambda c: c.scheme.value)
def test_materialized_stage_is_pytree(comp, field_2d):
    import jax
    e = comp.encode(_c(comp, field_2d))
    m = materialize(e, Stage.Q)
    leaves = jax.tree_util.tree_leaves(m)
    assert leaves and m.nbytes == sum(x.size * x.dtype.itemsize for x in leaves)
    m2 = jax.tree.map(lambda x: x, m)
    assert isinstance(m2, MaterializedStage) and m2.sig() == m.sig()
    # stacking (what the engine's seeded programs do) keeps the treedef
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), m, m2)
    assert stacked.q_spatial.shape == (2,) + m.q_spatial.shape


def test_materialize_rejects_stage_m(field_2d):
    e = hszx_nd.encode(_c(hszx_nd, field_2d))
    with pytest.raises(ValueError, match="already resident"):
        materialize(e, Stage.M)


def test_mismatched_seed_rejected(field_2d):
    e = hszp_nd.encode(_c(hszp_nd, field_2d))
    m_q = materialize(e, Stage.Q)
    with pytest.raises(ValueError, match="does not match"):
        H.compute(e, "mean", Stage.P, seed=m_q)
    m_reg = materialize(e, Stage.Q, region=REGION, closure="hull")
    with pytest.raises(ValueError, match="does not match"):
        H.compute(e, "mean", Stage.Q, seed=m_reg)  # region key mismatch


# -- bit-identical store-backed queries: every (scheme, op-set, stage, ±region)

@pytest.mark.parametrize("comp", ALL, ids=lambda c: c.scheme.value)
@pytest.mark.parametrize("ops", FUSED_SETS, ids="+".join)
def test_store_backed_bit_identical(comp, ops, field_2d):
    c = _c(comp, field_2d)
    e = comp.encode(c)
    for field in (c, e):
        store = FieldStore()
        store.put("f", field)
        eng = BatchedAnalytics()
        for stage in _shared_stages(comp.scheme, ops):
            for region in (None, REGION):
                ref = query([field], list(ops), stage=stage, engine=eng,
                            region=region)
                got = query(["f"], list(ops), stage=stage, engine=eng,
                            region=region, store=store)
                hot = query(["f"], list(ops), stage=stage, engine=eng,
                            region=region, store=store)
                # first call misses (unless stage ④ reuses the ③ entry),
                # second is always served resident
                assert got.store_misses + got.store_hits >= 1
                assert hot.store_misses == 0 and hot.store_hits >= 1
                for op in ops:
                    _assert_same(got.values[0][op], ref.values[0][op])
                    _assert_same(hot.values[0][op], ref.values[0][op])


@pytest.mark.parametrize("comp", [hszp_nd, hszx_nd], ids=lambda c: c.scheme.value)
@pytest.mark.parametrize("ops", [("divergence",), ("divergence", "curl")],
                         ids="+".join)
def test_store_backed_vector_bit_identical(comp, ops, vector_field_2d):
    u, v = vector_field_2d
    cu, cv = _c(comp, u), _c(comp, v)
    store = FieldStore()
    store.put("u", cu)
    store.put("v", cv)
    eng = BatchedAnalytics()
    region = ((20, 60), (40, 90))
    for stage in _shared_stages(comp.scheme, ops):
        for r in (None, region):
            ref = query([(cu, cv)], list(ops), stage=stage, engine=eng,
                        region=r)
            for _ in range(2):  # miss pass, then hit pass
                got = query([("u", "v")], list(ops), stage=stage, engine=eng,
                            region=r, store=store)
                for op in ops:
                    _assert_same(got.values[0][op], ref.values[0][op])
            assert got.store_hits >= 2  # both components served hot


def test_store_backed_batch_and_mixed_inputs(field_2d):
    """Ids and raw containers mix in one query; ids group separately (only
    they can seed) but every value matches the storeless reference."""
    cs = _compress_many(hszp_nd, 4)
    store = FieldStore()
    for i, c in enumerate(cs[:2]):
        store.put(f"f{i}", c)
    eng = BatchedAnalytics()
    ref = query(cs, ["mean", "std"], stage=Stage.P, engine=eng)
    got = query(["f0", "f1", cs[2], cs[3]], ["mean", "std"], stage=Stage.P,
                engine=eng, store=store)
    assert got.n_batches == 2  # store-backed vs raw split
    for i in range(4):
        for op in ("mean", "std"):
            _assert_same(got.values[i][op], ref.values[i][op])


def test_seeded_engine_program_is_separate_and_equal(field_2d):
    eng = BatchedAnalytics()
    cs = _compress_many(hszp_nd, 3)
    store = FieldStore()
    ids = [store.put(f"f{i}", c) for i, c in enumerate(cs)]
    cold = query(cs, "std", stage=Stage.Q, engine=eng)
    assert eng.cache_size == 1
    hot = query(ids, "std", stage=Stage.Q, engine=eng, store=store)
    assert eng.cache_size == 2  # seeded program compiles separately
    for a, b in zip(cold.values, hot.values):
        _assert_same(b, a)
    query(ids, "std", stage=Stage.Q, engine=eng, store=store)
    assert eng.cache_size == 2  # and is reused on the hit path


def test_stage_f_std_accurate_for_mean_dominated_fields():
    """The stage-④ std (the accuracy reference) must be mean-subtracted: a
    single-pass moments form catastrophically cancels in f32 when the mean
    dominates the spread (1000 ± 0.1 -> garbage)."""
    rng = np.random.default_rng(3)
    d = (1000.0 + rng.normal(0, 0.1, (128, 128))).astype(np.float32)
    c = hszx_nd.compress(jnp.asarray(d), rel_eb=1e-4)
    got = float(H.std(c, Stage.F))
    assert abs(got - d.std(ddof=1)) < 1e-3, (got, d.std(ddof=1))


def test_vector_op_bare_string_id_rejected(field_2d):
    store = FieldStore()
    store.put("uv", _c(hszp_nd, field_2d))
    with pytest.raises(TypeError, match="per component"):
        query(["uv"], "curl", stage=Stage.Q, store=store)


# -- cache-aware auto planning ------------------------------------------------

def test_auto_flips_to_cached_stage_uncalibrated(field_2d):
    """Residency alone flips the plan: Lorenzo {mean, std} auto-plans ② cold,
    but a resident stage-③ materialization beats any reconstruction."""
    c = _c(hszp_nd, field_2d)
    store = FieldStore()
    store.put("f", c)
    eng = BatchedAnalytics()
    cold = query([c], ["mean", "std"], engine=eng)
    assert cold.stages[0] == {"mean": Stage.P, "std": Stage.P}
    store.ensure("f", Stage.Q)
    hot = query(["f"], ["mean", "std"], engine=eng, store=store)
    assert hot.stages[0] == {"mean": Stage.Q, "std": Stage.Q}
    assert hot.store_hits >= 1
    ref = query([c], ["mean", "std"], stage=Stage.Q, engine=eng)
    for op in ("mean", "std"):
        _assert_same(hot.values[0][op], ref.values[0][op])


def test_auto_flip_calibrated_reconstruction_term():
    """With measured costs, a cached stage is priced at cost minus the fig34
    reconstruction term — which flips the choice exactly when that term is
    what made the higher stage lose."""
    scheme = Scheme.HSZP_ND
    cm = CostModel()
    for op in ("mean", "std"):
        cm.record(scheme, op, Stage.P, 100.0)
        cm.record(scheme, op, Stage.Q, 120.0)
        cm.record(scheme, op, Stage.F, 500.0)
    cm.record_reconstruction(scheme, Stage.Q, 110.0)
    # cold: P wins (200 < 240)
    assert analytics.plan_stages(scheme, ["mean", "std"],
                                 cost_model=cm).fused == Stage.P
    # Q resident: 2 * (120 - 110) = 20 < 200 -> flips to Q
    plan = analytics.plan_stages(scheme, ["mean", "std"], cost_model=cm,
                                 cached=frozenset({Stage.Q}))
    assert plan.fused == Stage.Q
    # a cached stage never goes below zero cost, and stage order breaks ties
    cm.record_reconstruction(scheme, Stage.P, 500.0)
    assert cm.cost(scheme, "mean", Stage.P, cached=True) == 0.0


def test_unmeasured_reconstruction_discount_is_conservative():
    """A cached stage with no measured reconstruction must not undercut a
    measured rival on made-up numbers: the fallback discount is the largest
    reconstruction measured at a *lower* stage (monotone in stage), so a
    stage-③ entry that also serves stage ④ still routes the plan to ③."""
    scheme = Scheme.HSZP_ND
    cm = CostModel()
    for op, p, q, f in (("mean", 90.0, 130.0, 700.0),
                        ("std", 95.0, 140.0, 700.0)):
        cm.record(scheme, op, Stage.P, p)
        cm.record(scheme, op, Stage.Q, q)
        cm.record(scheme, op, Stage.F, f)
    cm.record_reconstruction(scheme, Stage.Q, 80.0)
    # Q entry resident => both Q and F count as cached; F's reconstruction
    # is unmeasured and discounts by recon(Q)=80, keeping F at 1240 vs 110
    plan = analytics.plan_stages(scheme, ["mean", "std"], cost_model=cm,
                                 cached=frozenset({Stage.Q, Stage.F}))
    assert plan.fused == Stage.Q
    assert cm.cost(scheme, "mean", Stage.F, cached=True) == 620.0
    # with nothing measured at a lower stage there is no discount at all
    cm2 = CostModel()
    cm2.record(scheme, "mean", Stage.P, 90.0)
    cm2.record(scheme, "mean", Stage.Q, 130.0)
    assert cm2.cost(scheme, "mean", Stage.Q, cached=True) == 130.0


def test_plan_stage_cached_preference_keeps_metadata_fast_path():
    """Stage ① needs no reconstruction (metadata is resident in the
    container), so a cached higher stage must not displace it."""
    assert analytics.plan_stage(Scheme.HSZX_ND, "mean",
                                cached=frozenset({Stage.Q})) == Stage.M
    assert analytics.plan_stage(Scheme.HSZP_ND, "mean",
                                cached=frozenset({Stage.Q})) == Stage.Q


def test_cached_stages_requires_matching_region_and_closure(field_2d):
    c = _c(hszp_nd, field_2d)
    store = FieldStore()
    store.put("f", c)
    store.ensure("f", Stage.Q)
    # the stage-③ integers serve stage ④ too (dequantize is postlude)
    assert store.cached_stages("f", ["mean", "std"]) == {Stage.Q, Stage.F}
    # the full-field entry does not serve a region query (different key) ...
    assert store.cached_stages("f", ["mean", "std"], region=REGION) == frozenset()
    cl = oplib.set_closure(["mean", "std"], c.scheme, Stage.Q)
    store.ensure("f", Stage.Q, region=REGION, closure=cl)
    assert store.cached_stages("f", ["mean", "std"],
                               region=REGION) == {Stage.Q, Stage.F}
    # ... and closures are part of the key: a stage-② derivative band entry
    # is not the hull the {mean, std} set needs
    band = oplib.set_closure("derivative", c.scheme, Stage.P, axis=0)
    hull = oplib.set_closure(["mean", "std"], c.scheme, Stage.P)
    assert band != hull
    store.ensure("f", Stage.P, region=REGION, closure=band)
    assert Stage.P not in store.cached_stages("f", ["mean", "std"],
                                              region=REGION)
    assert Stage.P in store.cached_stages("f", "derivative", region=REGION)


# -- FieldStore semantics -----------------------------------------------------

def test_field_registry_semantics(field_2d):
    c = _c(hszx_nd, field_2d)
    store = FieldStore()
    store.put("a", c)
    assert "a" in store and store.get("a") is c and store.ids() == ("a",)
    with pytest.raises(ValueError, match="already registered"):
        store.put("a", c)
    with pytest.raises(KeyError, match="unknown field id"):
        store.get("missing")
    with pytest.raises(TypeError):
        store.put("b", np.zeros(4))
    with pytest.raises(ValueError):
        store.put("", c)


def test_replace_and_remove_invalidate_materializations(field_2d):
    c1 = _c(hszx_nd, field_2d)
    c2 = _c(hszx_nd, field_2d * 2.0)
    store = FieldStore()
    store.put("a", c1)
    store.ensure("a", Stage.Q)
    assert store.cache_entries == 1
    store.put("a", c2, replace=True)
    assert store.cache_entries == 0  # stale intermediate dropped
    assert store.stats.evictions == 1  # invalidation counts as churn
    m = store.ensure("a", Stage.Q)
    _assert_same(m.q_spatial, materialize(c2, Stage.Q).q_spatial)
    store.remove("a")
    assert "a" not in store and store.cache_entries == 0
    assert store.cache_bytes_in_use == 0


def test_lru_eviction_under_byte_budget(field_2d):
    c = _c(hszx_nd, field_2d)
    one = materialize(c, Stage.Q).nbytes
    store = FieldStore(cache_bytes=int(2.5 * one))
    for i in range(3):
        store.put(f"f{i}", c)
    store.ensure("f0", Stage.Q)
    store.ensure("f1", Stage.Q)
    assert store.cache_entries == 2
    store.lookup("f0", Stage.Q)          # refresh f0 -> f1 becomes LRU
    store.ensure("f2", Stage.Q)          # budget forces one eviction
    assert store.cache_entries == 2
    assert store.stats.evictions == 1
    assert store.cache_bytes_in_use <= store.cache_bytes
    assert store.lookup("f0", Stage.Q) is not None   # survivor
    assert store.lookup("f1", Stage.Q) is None       # evicted (miss)
    assert (store.stats.hits, store.stats.misses) == (2, 4)


def test_oversized_entry_not_retained(field_2d):
    c = _c(hszx_nd, field_2d)
    store = FieldStore(cache_bytes=16)   # smaller than any materialization
    store.put("a", c)
    m = store.ensure("a", Stage.Q)       # still computed and returned ...
    assert m.q_spatial is not None
    assert store.cache_entries == 0      # ... but never resident
    # counted as a rejection, not an eviction (it was never resident)
    assert store.stats.rejected == 1 and store.stats.evictions == 0
    # seed() declines outright (no wasted reconstruction), so queries fall
    # back to unseeded execution instead of re-materializing every call
    assert store.seed("a", Stage.Q) is None
    assert store.stats.rejected == 2
    misses0 = store.stats.misses
    res = query(["a"], ["mean", "std"], stage=Stage.Q, store=store)
    ref = query([c], ["mean", "std"], stage=Stage.Q)
    for op in ("mean", "std"):
        _assert_same(res.values[0][op], ref.values[0][op])
    assert store.stats.misses == misses0  # never touched the cache again


@pytest.mark.parametrize("comp", ALL, ids=lambda c: c.scheme.value)
def test_materialized_nbytes_predicts_exactly(comp, field_2d):
    """The static size predictor must equal the realized nbytes — it is the
    retention decision, so drift would retain unboundedly or decline hot
    cells."""
    from repro.store import materialized_nbytes
    e = comp.encode(_c(comp, field_2d))
    for stage in (Stage.P, Stage.Q, Stage.F):
        for region, cl in ((None, "cover"),
                           (REGION, oplib.set_closure(["mean", "std"],
                                                      e.scheme, stage))):
            predicted = materialized_nbytes(e, stage, region=region,
                                            closure=cl)
            actual = materialize(e, stage, region=region, closure=cl).nbytes
            assert predicted == actual, (stage, region)


# -- serving by field id ------------------------------------------------------

def test_serve_resolves_field_ids_one_dispatch_per_group(field_2d):
    cs = _compress_many(hszx_nd, 3)
    store = FieldStore()
    for i, c in enumerate(cs):
        store.put(f"fields/{i}", c)
    fe = AnalyticsFrontend(store=store)
    for i in range(3):
        fe.add_request(AnalyticsRequest(uid=i, fields=f"fields/{i}",
                                        op=["mean", "std"]))
    done = {r.uid: r for r in fe.run_until_drained()}
    assert all(r.error is None for r in done.values())
    assert fe.engine.cache_size == 1     # the whole id group: one program
    stage = done[0].result_stage["mean"]
    import jax
    refs = {op: jax.jit(lambda f, o=op: getattr(H, o)(f, stage))
            for op in ("mean", "std")}
    for i in range(3):
        _assert_same(done[i].result["mean"], refs["mean"](cs[i]))
        _assert_same(done[i].result["std"], refs["std"](cs[i]))
    # second round is served from resident materializations
    h0 = store.stats.hits
    fe.add_request(AnalyticsRequest(uid=9, fields="fields/0", op=["mean", "std"]))
    done = fe.run_until_drained()
    assert done[0].error is None and store.stats.hits > h0


def test_serve_vector_ids_and_rejections(field_2d, vector_field_2d):
    u, v = vector_field_2d
    store = FieldStore()
    store.put("u", _c(hszp_nd, u))
    store.put("v", _c(hszp_nd, v))
    fe = AnalyticsFrontend(store=store)
    fe.add_request(AnalyticsRequest(uid=0, fields=("u", "v"), op="curl"))
    fe.add_request(AnalyticsRequest(uid=1, fields="ghost", op="mean"))
    done = {r.uid: r for r in fe.run_until_drained()}
    assert done[0].error is None
    import jax
    ref = jax.jit(lambda a, b: H.curl([a, b], done[0].result_stage))
    _assert_same(done[0].result, ref(store.get("u"), store.get("v")))
    assert done[1].error is not None and "ghost" in done[1].error


def test_serve_ids_without_store_rejected(field_2d):
    fe = AnalyticsFrontend()             # no store attached
    fe.add_request(AnalyticsRequest(uid=0, fields="some/id", op="mean"))
    (r,) = fe.run_until_drained()
    assert r.error is not None and "store" in r.error


# -- CostModel persistence ----------------------------------------------------

def test_cost_model_save_load_roundtrip(tmp_path):
    cm = CostModel()
    cm.record(Scheme.HSZP_ND, "mean", Stage.P, 100.0)
    cm.record(Scheme.HSZP_ND, "mean", Stage.P, 200.0)   # running mean: 150
    cm.record(Scheme.HSZX, "std", Stage.Q, 42.0)
    cm.record_reconstruction(Scheme.HSZP_ND, Stage.Q, 80.0)
    path = tmp_path / "cost.json"
    cm.save(path)
    loaded = CostModel.load(path)
    assert loaded.table == cm.table
    assert loaded.recon == cm.recon
    assert loaded._counts == cm._counts
    # counts round-trip => post-load observations continue the same mean
    loaded.record(Scheme.HSZP_ND, "mean", Stage.P, 300.0)
    cm.record(Scheme.HSZP_ND, "mean", Stage.P, 300.0)
    assert loaded.table == cm.table
    # and the loaded model plans identically
    assert analytics.plan_stages(
        Scheme.HSZP_ND, ["mean"], cost_model=loaded).fused == Stage.P


def test_cost_model_load_rejects_foreign_json(tmp_path):
    path = tmp_path / "bogus.json"
    path.write_text('{"format": "something-else", "cells": []}')
    with pytest.raises(ValueError, match="not a hsz-cost-model"):
        CostModel.load(path)


def test_cost_model_calibrates_reconstruction_from_fig34_rows():
    csv = "\n".join([
        "name,us_per_call,derived",
        "fig34/Ocean/hszp_nd-q,80.0,GBps=1.0",
        "fig34/NYX/hszp_nd-q,120.0,GBps=1.0",
        "fig34/Ocean/hszp_nd-f,500.0,GBps=1.0",
        "fig58/Ocean/mean/hszp_nd-q,130.0,GBps=1.0",
        "fig58/Ocean/mean/hszp_nd-p,90.0,GBps=1.0",
        "fig58/Ocean/mean/hszp_nd-f,700.0,GBps=1.0",
    ])
    cm = CostModel.from_benchmark_csv(csv)
    assert cm.reconstruction(Scheme.HSZP_ND, Stage.Q) == 100.0  # mean of 2
    assert cm.reconstruction(Scheme.HSZP_ND, Stage.M) == 0.0
    assert cm.cost(Scheme.HSZP_ND, "mean", Stage.Q) == 130.0
    assert cm.cost(Scheme.HSZP_ND, "mean", Stage.Q, cached=True) == 30.0
    # cold: P (90 < 130); Q resident: 30 < 90 -> flip
    stages = (Stage.P, Stage.Q, Stage.F)
    assert cm.cheapest(Scheme.HSZP_ND, "mean", stages) == Stage.P
    assert cm.cheapest(Scheme.HSZP_ND, "mean", stages,
                       cached={Stage.Q}) == Stage.Q
