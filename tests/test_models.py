"""Per-arch smoke tests (reduced configs) + decode/train parity checks."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import ARCHS, reduced
from repro.configs.base import ShapeConfig
from repro.models import get_model, make_batch

KEY = jax.random.PRNGKey(0)
TRAIN = ShapeConfig("t", "train", 32, 2)

# decode parity is checked on one arch per decode-path implementation
PARITY_ARCHS = ["qwen3-4b", "deepseek-v3-671b", "falcon-mamba-7b",
                "recurrentgemma-9b", "whisper-base"]


@pytest.mark.parametrize("name", list(ARCHS), ids=list(ARCHS))
def test_train_step_smoke(name):
    """One forward/backward on the reduced config: shapes + no NaNs."""
    cfg = reduced(ARCHS[name])
    m = get_model(cfg)
    params, specs = m.init(KEY)
    # spec tree mirrors param tree
    assert jax.tree.structure(jax.tree.map(lambda _: 0, params)) == \
        jax.tree.structure(jax.tree.map(lambda _: 0, specs,
                                        is_leaf=lambda x: isinstance(x, tuple)))
    batch = make_batch(cfg, TRAIN, KEY)
    loss, grads = jax.value_and_grad(m.loss_fn)(params, batch)
    assert np.isfinite(float(loss))
    gn = sum(float(jnp.sum(jnp.abs(g).astype(jnp.float32))) for g in jax.tree.leaves(grads))
    assert np.isfinite(gn) and gn > 0


@pytest.mark.parametrize("name", list(ARCHS), ids=list(ARCHS))
def test_abstract_init_matches_concrete(name):
    """Dry-run abstract init must produce exactly the concrete shapes."""
    cfg = reduced(ARCHS[name])
    m = get_model(cfg)
    params, _ = m.init(KEY)
    abstract, _ = m.init(None)
    concrete_shapes = jax.tree.map(lambda x: (tuple(x.shape), str(x.dtype)), params)
    abstract_shapes = jax.tree.map(lambda x: (tuple(x.shape), str(x.dtype)), abstract)
    assert concrete_shapes == abstract_shapes


@pytest.mark.parametrize("name", PARITY_ARCHS, ids=PARITY_ARCHS)
def test_decode_matches_train_forward(name):
    """Teacher-forced decode through the cache == full-sequence forward."""
    cfg = reduced(ARCHS[name])
    m = get_model(cfg)
    params, _ = m.init(KEY)
    T = 8
    batch = make_batch(cfg, ShapeConfig("t", "train", T, 2), KEY)
    full = np.asarray(m.full_logits(params, batch))      # (B, T, V)

    cache = m.init_cache(2, T + (0 if cfg.family != "hybrid" else 0))
    if cfg.family == "audio":
        # cross-attention cache must be filled from the encoder output
        from repro.models import whisper, attention
        enc_out = whisper.encode(cfg, params, batch["frames"])
        acfg = whisper._acfg(cfg)
        ks, vs = [], []
        for l in range(cfg.n_layers):
            lp = jax.tree.map(lambda x, _l=l: x[_l], params["dec"])
            kv = attention.project_kv(
                lp["xattn"], enc_out,
                acfg, jnp.zeros(enc_out.shape[:2], jnp.int32))
            ks.append(kv["k"]), vs.append(kv["v"])
        cache["layers"]["cross"]["k"] = jnp.stack(ks).astype(cfg.compute_dtype)
        cache["layers"]["cross"]["v"] = jnp.stack(vs).astype(cfg.compute_dtype)
    logits_steps = []
    for t in range(T):
        logits, cache = m.decode_step(params, batch["tokens"][:, t:t + 1], cache)
        logits_steps.append(np.asarray(logits[:, 0, :]))
    dec = np.stack(logits_steps, axis=1)
    # bf16 compute: compare top-1 agreement + loose numeric tolerance
    agree = (dec.argmax(-1) == full.argmax(-1)).mean()
    assert agree > 0.9, f"top-1 agreement {agree}"
    np.testing.assert_allclose(dec, full, rtol=0.1, atol=0.15)


def test_vlm_prefix_handling():
    cfg = reduced(ARCHS["paligemma-3b"])
    m = get_model(cfg)
    params, _ = m.init(KEY)
    batch = make_batch(cfg, TRAIN, KEY)
    assert batch["prefix_embeds"].shape == (2, cfg.prefix_tokens, cfg.d_model)
    loss = m.loss_fn(params, batch)
    assert np.isfinite(float(loss))


def test_param_counts_full_configs():
    """Full-config parameter counts are in the advertised ballpark."""
    expected = {
        "qwen3-4b": (3.0e9, 6.5e9),
        "granite-3-2b": (2.0e9, 3.6e9),
        "smollm-360m": (0.3e9, 0.5e9),
        "minitron-4b": (3.5e9, 6.5e9),
        "falcon-mamba-7b": (6.0e9, 8.5e9),
        "whisper-base": (0.05e9, 0.2e9),
        "granite-moe-3b-a800m": (2.5e9, 4.5e9),
        "deepseek-v3-671b": (6.0e11, 7.5e11),
        "recurrentgemma-9b": (7.5e9, 11e9),
        "paligemma-3b": (2.2e9, 4.0e9),
    }
    for name, (lo, hi) in expected.items():
        n = ARCHS[name].param_count
        assert lo <= n <= hi, f"{name}: {n:.3e} not in [{lo:.1e}, {hi:.1e}]"


def test_moe_active_params():
    ds = ARCHS["deepseek-v3-671b"]
    assert ds.active_param_count < 0.1 * ds.param_count


@pytest.mark.parametrize("name", ["falcon-mamba-7b", "recurrentgemma-9b",
                                  "qwen3-4b"],
                         ids=["mamba", "griffin", "transformer"])
def test_prefill_then_decode_continuity(name):
    """prefill(prompt) -> decode continues exactly like step-by-step decode."""
    cfg = reduced(ARCHS[name])
    m = get_model(cfg)
    params, _ = m.init(KEY)
    P, EXTRA, MAXLEN = 6, 3, 16
    toks = jax.random.randint(jax.random.PRNGKey(3), (2, P + EXTRA), 1,
                              cfg.vocab, jnp.int32)

    # path A: teacher-forced decode from scratch
    cache_a = m.init_cache(2, MAXLEN)
    logits_a = None
    for t in range(P + EXTRA):
        logits_a, cache_a = m.decode_step(params, toks[:, t:t + 1], cache_a)

    # path B: prefill the prompt, then decode the EXTRA tokens
    _, cache_b = m.prefill(params, {"tokens": toks[:, :P]}, MAXLEN)
    logits_b = None
    for t in range(P, P + EXTRA):
        logits_b, cache_b = m.decode_step(params, toks[:, t:t + 1], cache_b)

    a = np.asarray(logits_a[:, -1], np.float32)
    b = np.asarray(logits_b[:, -1], np.float32)
    agree = (a.argmax(-1) == b.argmax(-1)).mean()
    assert agree == 1.0, f"top-1 agreement {agree}"
    np.testing.assert_allclose(a, b, rtol=0.1, atol=0.15)
