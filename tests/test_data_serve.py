"""Data pipelines (determinism, resume, homomorphic accessors) + serving."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import ARCHS, reduced
from repro.data.scientific import ScientificStore, synth_field
from repro.data.tokens import TokenPipeline, TokenPipelineConfig
from repro.models import get_model
from repro.serve import Engine, Request

KEY = jax.random.PRNGKey(0)


# -- token pipeline -----------------------------------------------------------

def test_token_pipeline_deterministic_and_resumable():
    cfg = TokenPipelineConfig(vocab=1000, seq_len=16, global_batch=4)
    a = TokenPipeline(cfg)
    batches = [next(a)["tokens"] for _ in range(5)]
    # resume from step 3 reproduces batches 3,4
    b = TokenPipeline(cfg, start_step=3)
    np.testing.assert_array_equal(next(b)["tokens"], batches[3])
    np.testing.assert_array_equal(next(b)["tokens"], batches[4])
    # state dict roundtrip
    state = a.state_dict()
    c = TokenPipeline(cfg)
    c.load_state_dict(state)
    np.testing.assert_array_equal(next(c)["tokens"], next(a)["tokens"])


def test_token_pipeline_sharding_partitions():
    cfg0 = TokenPipelineConfig(vocab=100, seq_len=8, global_batch=8, n_shards=2, shard=0)
    cfg1 = TokenPipelineConfig(vocab=100, seq_len=8, global_batch=8, n_shards=2, shard=1)
    b0 = TokenPipeline(cfg0).batch_at(0)["tokens"]
    b1 = TokenPipeline(cfg1).batch_at(0)["tokens"]
    assert b0.shape == (4, 8) and b1.shape == (4, 8)
    assert not np.array_equal(b0, b1)  # shards see different data


# -- scientific store ----------------------------------------------------------

def test_scientific_store_homomorphic_stats():
    store = ScientificStore(scale=24, rel_eb=1e-3)
    raw = np.asarray(store.raw("Ocean", 0))
    st = store.stats("Ocean", 0)
    # stage-1/2 stats vs numpy on the DECOMPRESSED field: within paper bounds
    assert abs(st["mean"] - raw.mean()) <= 2e-3 * (raw.max() - raw.min())
    assert abs(st["std"] - raw.std(ddof=1)) <= 2e-3 * (raw.max() - raw.min())


def test_scientific_store_derivative_features():
    store = ScientificStore(scale=24, rel_eb=1e-3)
    g = store.derivative_features("Ocean", 1)
    raw = np.asarray(store.raw("Ocean", 1))
    ref0 = (raw[2:, 1:-1] - raw[:-2, 1:-1]) * 0.5
    np.testing.assert_allclose(np.asarray(g[0]), ref0, atol=1e-5, rtol=1e-4)


def test_scientific_store_disk_roundtrip(tmp_path):
    store = ScientificStore(scale=48, root=str(tmp_path))
    s1 = store.stats("Miranda", 0)
    store2 = ScientificStore(scale=48, root=str(tmp_path))  # reads from disk
    s2 = store2.stats("Miranda", 0)
    assert s1 == s2


def test_synth_field_deterministic():
    a = synth_field("NYX", 2, (16, 16, 16))
    b = synth_field("NYX", 2, (16, 16, 16))
    np.testing.assert_array_equal(a, b)


# -- serving engine --------------------------------------------------------------

@pytest.fixture(scope="module")
def small_model():
    cfg = reduced(ARCHS["smollm-360m"])
    m = get_model(cfg)
    params, _ = m.init(KEY)
    return cfg, m, params


def test_engine_drains_requests(small_model):
    cfg, m, params = small_model
    eng = Engine(m, params, slots=2, max_len=64)
    rng = np.random.default_rng(0)
    reqs = [Request(uid=i, prompt=rng.integers(1, cfg.vocab, 5).astype(np.int32),
                    max_new_tokens=4) for i in range(5)]
    for r in reqs:
        eng.add_request(r)
    done = eng.run_until_drained()
    assert len(done) == 5
    for r in done:
        assert len(r.out_tokens) == 4
        assert all(0 <= t < cfg.vocab for t in r.out_tokens)


def test_engine_greedy_matches_manual_decode(small_model):
    """Single request: engine output == manual greedy decode loop."""
    cfg, m, params = small_model
    prompt = np.asarray([5, 17, 3], np.int32)
    eng = Engine(m, params, slots=1, max_len=32)
    eng.add_request(Request(uid=0, prompt=prompt, max_new_tokens=5))
    out = eng.run_until_drained()[0].out_tokens

    cache = m.init_cache(1, 32)
    toks = list(prompt)
    logits = None
    for t in toks:
        logits, cache = m.decode_step(params, jnp.asarray([[t]], jnp.int32), cache)
    manual = []
    for _ in range(5):
        nxt = int(jnp.argmax(logits[0, -1]))
        manual.append(nxt)
        logits, cache = m.decode_step(params, jnp.asarray([[nxt]], jnp.int32), cache)
    assert out == manual
