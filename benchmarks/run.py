"""Benchmark harness: one benchmark per paper table/figure + framework benches.

Prints ``name,us_per_call,derived`` CSV rows.  Datasets are deterministic
synthetic analogues of the paper's five benchmarks (Table III), scaled by
``--scale`` (default 8: Ocean 300x450, NYX 64^3, ...) so the suite runs on
one CPU core; the compressor/operator code paths are identical at any scale.

Paper figure -> benchmark:
  Fig. 2   compression ratios                -> fig2_compression_ratio
  Fig. 3/4 decompression throughput by stage -> fig34_decompression
  Fig. 5-8 mean/std throughput by stage      -> fig58_statistics
  Fig. 9/10 derivative/Laplacian throughput  -> fig910_differentiation
  Fig. 11/12 divergence/curl throughput      -> fig1112_multivariate
  Table IV decompression/compute breakdown   -> table4_breakdown
  Table V  homomorphic operation errors      -> table5_op_errors
Framework-level (beyond paper):
  checkpoint bytes + homomorphic validation  -> fw_checkpoint
  compressed-collective wire bytes           -> fw_collective_bytes
  fused op sets vs sequential single ops     -> fw_fused_analytics
  expression DAGs vs per-leaf recompute      -> fw_expr_analytics
  store-backed hot-cache vs cold queries     -> fw_store_analytics
  streaming append+query vs re-encode        -> fw_stream_analytics
  fused Pallas kernels vs XLA lowering       -> fw_kernel_analytics
  sharded store vs single-device bytes/wall  -> fw_shard_analytics

``--filter PREFIX[,PREFIX...]`` runs only the row families whose name
starts with a prefix (e.g. ``--filter fw_store`` or ``--filter fig2,fw_``),
so CI gates and local iteration stop paying for the whole suite.

``--json-dir DIR`` writes every machine-readable row family as
``BENCH_*.json`` under DIR for the CI regression gates; the per-family
``--json`` / ``--json-expr`` / ``--json-store`` / ``--json-stream`` /
``--json-kernel`` flags remain as deprecated aliases.
"""
from __future__ import annotations
from collections.abc import Callable

import argparse
import json
import os
import time
import warnings

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import Stage, by_name, encode, homomorphic as H
from repro.core import region as region_mod
from repro.data.scientific import dataset_dims, synth_field

ROWS: list[tuple[str, float, str]] = []
FUSED_JSON: list[dict] = []
EXPR_JSON: list[dict] = []
STORE_JSON: list[dict] = []
STREAM_JSON: list[dict] = []
KERNEL_JSON: list[dict] = []
SHARD_JSON: list[dict] = []
SCALE = 8
REPS = 3

#: every machine-readable row family --json-dir emits, one file per gate
JSON_FILES = (("BENCH_fused.json", FUSED_JSON),
              ("BENCH_expr.json", EXPR_JSON),
              ("BENCH_store.json", STORE_JSON),
              ("BENCH_stream.json", STREAM_JSON),
              ("BENCH_kernel.json", KERNEL_JSON),
              ("BENCH_shard.json", SHARD_JSON))

COMPRESSORS = ["hszp", "hszx", "hszp_nd", "hszx_nd"]
EBS = [1e-1, 1e-2, 1e-3]
BENCH_SETS = ["Ocean", "Miranda", "NYX"]


def row(name: str, us: float, derived: str):
    ROWS.append((name, us, derived))


def timeit(fn: Callable, *args) -> tuple[float, object]:
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(REPS):
        out = fn(*args)
        jax.block_until_ready(out)
    return (time.perf_counter() - t0) / REPS * 1e6, out


def best_of(fn: Callable, *args, k: int = 7) -> float:
    """Min-of-k microseconds: contention only ever inflates a timing, so the
    minimum is the robust estimator the CI speedup gates need."""
    out = fn(*args)
    jax.block_until_ready(out)
    best = float("inf")
    for _ in range(max(k, REPS)):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best * 1e6


def _fields():
    for ds in BENCH_SETS:
        dims = dataset_dims(ds, SCALE)
        yield ds, jnp.asarray(synth_field(ds, 0, dims))


# ---------------------------------------------------------------------------

def fig2_compression_ratio():
    for ds, data in _fields():
        for name in COMPRESSORS:
            comp = by_name(name)
            for eb in EBS:
                c = comp.compress(data, rel_eb=eb)
                ratio = float(comp.compression_ratio(c))
                row(f"fig2/{ds}/{name}/eb{eb:g}", 0.0, f"ratio={ratio:.2f}")


def fig34_decompression():
    for ds, data in _fields():
        nbytes = data.size * 4
        for name in COMPRESSORS:
            comp = by_name(name)
            c = comp.compress(data, rel_eb=1e-2)
            e = comp.encode(c)
            for stage, tag in ((Stage.P, "p"), (Stage.Q, "q"), (Stage.F, "f")):
                fn = jax.jit(lambda enc, s=stage: comp.decompress(enc, s))
                us, _ = timeit(fn, e)
                gbps = nbytes / (us * 1e-6) / 1e9
                row(f"fig34/{ds}/{name}-{tag}", us, f"GBps={gbps:.2f}")


def fig58_statistics():
    for ds, data in _fields():
        nbytes = data.size * 4
        for name in COMPRESSORS:
            comp = by_name(name)
            c = comp.compress(data, rel_eb=1e-2)
            e = comp.encode(c)
            stages = [(Stage.P, "p"), (Stage.Q, "q"), (Stage.F, "f")]
            if comp.scheme.is_blockmean:
                stages.insert(0, (Stage.M, "m"))
            for op_name, op in (("mean", H.mean), ("std", H.std)):
                for stage, tag in stages:
                    if op_name == "std" and stage == Stage.M:
                        continue
                    fn = jax.jit(lambda enc, s=stage, o=op: o(enc, s))
                    us, _ = timeit(fn, e)
                    gbps = nbytes / (us * 1e-6) / 1e9
                    row(f"fig58/{ds}/{op_name}/{name}-{tag}", us, f"GBps={gbps:.2f}")


def fig910_differentiation():
    for ds, data in _fields():
        nbytes = data.size * 4
        for name in ("hszp_nd", "hszx_nd"):
            comp = by_name(name)
            c = comp.compress(data, rel_eb=1e-2)
            e = comp.encode(c)
            for op_name, op in (("deriv", lambda enc, s: H.derivative(enc, s, 0)),
                                ("laplacian", H.laplacian)):
                for stage, tag in ((Stage.P, "p"), (Stage.Q, "q"), (Stage.F, "f")):
                    fn = jax.jit(lambda enc, s=stage, o=op: o(enc, s))
                    us, _ = timeit(fn, e)
                    gbps = nbytes / (us * 1e-6) / 1e9
                    row(f"fig910/{ds}/{op_name}/{name}-{tag}", us, f"GBps={gbps:.2f}")


def fig1112_multivariate():
    for ds in BENCH_SETS:
        dims = dataset_dims(ds, SCALE)
        nd = len(dims)
        for name in ("hszp_nd", "hszx_nd"):
            comp = by_name(name)
            fields = [comp.encode(comp.compress(
                jnp.asarray(synth_field(ds, i, dims)), rel_eb=1e-2))
                for i in range(nd)]
            nbytes = nd * int(np.prod(dims)) * 4
            for op_name, op in (("div", H.divergence), ("curl", H.curl)):
                for stage, tag in ((Stage.P, "p"), (Stage.Q, "q"), (Stage.F, "f")):
                    fn = jax.jit(lambda *fs, s=stage, o=op: o(list(fs), s))
                    us, _ = timeit(fn, *fields)
                    gbps = nbytes / (us * 1e-6) / 1e9
                    row(f"fig1112/{ds}/{op_name}/{name}-{tag}", us, f"GBps={gbps:.2f}")


def table4_breakdown():
    """Decompression vs computation split for a 3-D derivative (NYX)."""
    dims = dataset_dims("NYX", SCALE)
    data = jnp.asarray(synth_field("NYX", 0, dims))
    comp = by_name("hszp_nd")
    c = comp.compress(data, rel_eb=1e-3)
    e = comp.encode(c)
    us_dec_p, _ = timeit(jax.jit(lambda enc: encode.decode_device(enc).residuals), e)
    us_op_p, _ = timeit(jax.jit(lambda enc: H.derivative(enc, Stage.P, 0)), e)
    us_dec_q, _ = timeit(jax.jit(lambda enc: comp.decompress(enc, Stage.Q)), e)
    us_op_q, _ = timeit(jax.jit(lambda enc: H.derivative(enc, Stage.Q, 0)), e)
    us_dec_f, _ = timeit(jax.jit(lambda enc: comp.decompress(enc, Stage.F)), e)
    us_op_f, _ = timeit(jax.jit(lambda enc: H.derivative(enc, Stage.F, 0)), e)
    row("table4/Dp", us_op_p, f"decode_us={us_dec_p:.0f}")
    row("table4/Dq", us_op_q, f"decode_us={us_dec_q:.0f}")
    row("table4/Df", us_op_f, f"decode_us={us_dec_f:.0f}")


def table5_op_errors():
    dims = dataset_dims("NYX", SCALE)
    u = jnp.asarray(synth_field("NYX", 0, dims))
    v = jnp.asarray(synth_field("NYX", 1, dims))
    w = jnp.asarray(synth_field("NYX", 2, dims))
    for name in COMPRESSORS:
        comp = by_name(name)
        cu = comp.compress(u, rel_eb=1e-3)
        errs = {}
        ref = float(H.mean(cu, Stage.F))
        stages = [Stage.P, Stage.Q] + ([Stage.M] if comp.scheme.is_blockmean else [])
        errs["mean"] = max(abs(float(H.mean(cu, s)) - ref) / max(abs(ref), 1e-12)
                           for s in stages)
        ref = float(H.std(cu, Stage.F))
        errs["std"] = max(abs(float(H.std(cu, s)) - ref) / ref
                          for s in (Stage.P, Stage.Q))
        if comp.scheme.is_nd:
            cv, cw = comp.compress(v, rel_eb=1e-3), comp.compress(w, rel_eb=1e-3)
            for op_name, fn in (
                    ("deriv", lambda s: H.derivative(cu, s, 0)),
                    ("laplacian", lambda s: H.laplacian(cu, s)),
                    ("div", lambda s: H.divergence([cu, cv, cw], s)),
                    ("curl", lambda s: H.curl([cu, cv, cw], s)[0])):
                refv = np.asarray(fn(Stage.F))
                scale = max(np.abs(refv).max(), 1e-12)
                errs[op_name] = max(
                    float(np.abs(np.asarray(fn(s)) - refv).max()) / scale
                    for s in (Stage.P, Stage.Q))
        for k, val in errs.items():
            row(f"table5/{name}/{k}", 0.0, f"max_rel_err={val:.2e}")


def fw_checkpoint():
    """HSZ checkpoints: bytes vs zstd-lossless + homomorphic validation."""
    import os
    import tempfile
    from repro.train import checkpoint as ckpt
    rng = np.random.default_rng(0)
    params = {"w": jnp.asarray(np.cumsum(rng.normal(0, 1e-2, (512, 256)),
                                         axis=0).astype(np.float32)),
              "b": jnp.asarray(rng.normal(0, 1e-2, (4096,)).astype(np.float32))}
    raw = sum(np.asarray(v).nbytes for v in params.values())
    for mode in ("lossless", "hsz"):
        with tempfile.TemporaryDirectory() as d:
            t0 = time.perf_counter()
            ckpt.save(d, 0, params, mode=mode, rel_eb=1e-4)
            us = (time.perf_counter() - t0) * 1e6
            step_dir = os.path.join(d, "step_00000000")
            total = sum(os.path.getsize(os.path.join(step_dir, "arrays", f))
                        for f in os.listdir(os.path.join(step_dir, "arrays")))
            row(f"fw_ckpt/{mode}", us, f"bytes={total} ratio={raw/total:.2f}")


def fw_batched_analytics():
    """Batched vmap analytics vs an equal-work per-field jitted loop.

    Same fields, same op, same stage: the batched engine issues ONE dispatch
    (stack fused into the compiled program) where the loop issues one per
    field.  The workload is the serving regime this engine exists for — many
    small same-layout fields (timestep/variable tiles), where per-call
    dispatch dominates — so the tile size is fixed rather than scaled by
    ``--scale`` (per-op throughput vs size is covered by fig3-12).
    """
    from repro.analytics import BatchedAnalytics, plan_stage

    batch, tile = 64, (64, 64)
    for name in ("hszp_nd", "hszx_nd"):
        comp = by_name(name)
        fields = [comp.compress(jnp.asarray(synth_field("Ocean", 0, tile, seed=i)),
                                rel_eb=1e-2) for i in range(batch)]
        eng = BatchedAnalytics()
        for op_name, op in (("mean", H.mean), ("std", H.std),
                            ("derivative", lambda c, s: H.derivative(c, s, 0))):
            stage = plan_stage(comp.scheme, op_name)
            us_batched, _ = timeit(
                lambda fs, _o=op_name, _s=stage: eng.run(fs, _o, _s), fields)
            loop_fn = jax.jit(lambda c, s=stage, o=op: o(c, s))

            def per_field_loop(fs):
                return [loop_fn(c) for c in fs]

            us_loop, _ = timeit(per_field_loop, fields)
            row(f"fw_batched_analytics/{name}/{op_name}", us_batched,
                f"loop_us={us_loop:.1f} speedup={us_loop / us_batched:.2f}x "
                f"batch={batch} stage={stage.name}")


def fw_fused_analytics():
    """Fused op sets vs sequential single-op queries at one shared stage.

    Same fields, same ops, same stage: the fused program lowers the whole op
    set onto ONE stage reconstruction (``repro.core.oplib``) and issues one
    dispatch, where the sequential baseline re-decodes per op and dispatches
    per op.  The fields are *encoded* (bit-packed) — the paper's serving
    representation — so every sequential op pays the payload unpack the
    fused program pays once.  Both sides run through the batched engine
    (warm jit cache), so the speedup isolates exactly what fusion saves:
    the repeated decode + recorrelation prelude and the per-op dispatch
    overhead.  Rows cover both shared stages (② and ③): how much fusion
    saves is stage-dependent — stage ③ shares the *whole* recorrelation
    pass, stage ② only the decode plus whatever intermediates the set has
    in common — and the calibrated joint planner exists precisely to route
    an op set to the stage where the shared prelude wins.  Like
    ``fw_batched_analytics`` this pins the serving regime (many small
    same-layout fields) rather than scaling with ``--scale``.
    """
    from repro.analytics import BatchedAnalytics

    batch, tile = 32, (64, 64)
    ops = ("mean", "std", "laplacian")
    for name in ("hszp_nd", "hszx_nd"):
        comp = by_name(name)
        cs = [comp.compress(jnp.asarray(synth_field("Ocean", 0, tile, seed=i)),
                            rel_eb=1e-2) for i in range(batch)]
        bits = max(comp.max_bits(c) for c in cs)
        fields = [comp.encode(c, bits=bits) for c in cs]
        eng = BatchedAnalytics()
        for stage, tag in ((Stage.P, "p"), (Stage.Q, "q")):
            us_fused = best_of(lambda fs, s=stage: eng.run(fs, ops, s),
                               fields)

            def sequential(fs, s=stage):
                return [eng.run(fs, op, s) for op in ops]

            us_seq = best_of(sequential, fields)
            speedup = us_seq / us_fused
            row_name = f"fw_fused_analytics/{name}/{'+'.join(ops)}-{tag}"
            row(row_name, us_fused,
                f"seq_us={us_seq:.1f} speedup={speedup:.2f}x batch={batch}")
            FUSED_JSON.append({"name": row_name, "scheme": name,
                               "stage": stage.name, "us": round(us_fused, 1),
                               "speedup": round(speedup, 3)})


def fw_expr_analytics():
    """Expression DAGs vs naive per-leaf recompute of the same derived ops.

    Three classic derived quantities over one encoded (u, v) velocity pair —
    vorticity ``ddx(v) - ddy(u)``, divergence ``ddx(u) + ddy(v)`` and the
    stretching deformation ``ddx(u) - ddy(v)`` — as ONE expression program
    (DESIGN.md §10): four distinct derivative nodes over two leaves, each
    leaf reconstructed exactly once, one compiled dispatch for all three
    roots.  The naive baseline spells the same math the only way the flat
    API allows: one single-derivative query per node (four dispatches, four
    stage reconstructions — u and v each unpacked and recorrelated twice)
    plus host-side combines.  Both sides run through warmed engine caches,
    so the speedup isolates what the DAG compiler saves: the duplicated
    leaf preludes and the per-node dispatch overhead.  Rows cover both
    shared stages (② and ③) per scheme; like the other fw serving benches
    the tile is pinned (per-op throughput vs size is covered by fig3-12).
    """
    from repro.analytics import query
    from repro.analytics.engine import BatchedAnalytics
    from repro.core import expr

    tile = (96, 96)
    for name in ("hszp_nd", "hszx_nd"):
        comp = by_name(name)
        u = comp.encode(comp.compress(
            jnp.asarray(synth_field("Ocean", 0, tile, seed=0)), rel_eb=1e-2))
        v = comp.encode(comp.compress(
            jnp.asarray(synth_field("Ocean", 1, tile, seed=1)), rel_eb=1e-2))
        ddx_u, ddy_u = expr.derivative(u, axis=0), expr.derivative(u, axis=1)
        ddx_v, ddy_v = expr.derivative(v, axis=0), expr.derivative(v, axis=1)
        roots = [ddx_v - ddy_u,   # vorticity
                 ddx_u + ddy_v,   # divergence
                 ddx_u - ddy_v]   # stretching deformation
        singles = [ddx_v, ddy_u, ddx_u, ddy_v]
        for stage, tag in ((Stage.P, "p"), (Stage.Q, "q")):
            eng = BatchedAnalytics()
            us_expr = best_of(lambda s=stage: query(
                exprs=roots, stage=s, engine=eng).values)

            eng2 = BatchedAnalytics()

            def naive(s=stage):
                dvx, duy, dux, dvy = [
                    query(exprs=[e], stage=s, engine=eng2).values[0]
                    for e in singles]
                return [dvx - duy, dux + dvy, dux - dvy]

            us_naive = best_of(naive)
            speedup = us_naive / us_expr
            row_name = f"fw_expr_analytics/{name}/vort+div+stretch-{tag}"
            row(row_name, us_expr,
                f"naive_us={us_naive:.1f} speedup={speedup:.2f}x "
                f"roots=3 leaves=2 nodes=4")
            EXPR_JSON.append({"name": row_name, "scheme": name,
                              "stage": stage.name, "us": round(us_expr, 1),
                              "naive_us": round(us_naive, 1),
                              "speedup": round(speedup, 3)})


def fw_region_analytics():
    """Region queries vs full-field queries at the same (scheme, op, stage).

    A ~10% window of the 2-D Ocean field: the region path unpacks only the
    window's closure blocks and computes only window elements, so its latency
    scales with the window (blockmean) or closure (Lorenzo prefix hull), not
    the field.  ``words`` reports the payload-gather sparsity that drives it.

    Caveat the rows keep honest: the full-field Lorenzo stage-② mean is
    already one contiguous rank-1 pass, so its region variant (scattered
    hull gather) can lose at large sizes — the calibrated region-aware cost
    model exists precisely to route such queries to a stage whose region
    closure wins (here ③).
    """
    dims = dataset_dims("Ocean", SCALE)
    data = jnp.asarray(synth_field("Ocean", 0, dims))
    for name in ("hszx_nd", "hszp_nd"):
        comp = by_name(name)
        c = comp.compress(data, rel_eb=1e-2)
        e = comp.encode(c)
        # ~31.6% extent per axis => ~10% of the area, away from the origin
        region = tuple((s // 8, min(s, s // 8 + max(4, int(s * 0.316))))
                       for s in c.shape)
        ops = (("mean", lambda enc, s, r: H.mean(enc, s, region=r)),
               ("deriv", lambda enc, s, r: H.derivative(enc, s, 0, region=r)))
        for op_name, op in ops:
            for stage, tag in ((Stage.P, "p"), (Stage.Q, "q")):
                fn_full = jax.jit(lambda enc, s=stage, o=op: o(enc, s, None))
                us_full, _ = timeit(fn_full, e)
                fn_reg = jax.jit(lambda enc, s=stage, o=op, r=region: o(enc, s, r))
                us_reg, _ = timeit(fn_reg, e)
                closure = region_mod.op_closure(comp.scheme, "derivative"
                                                if op_name == "deriv" else "mean",
                                                stage, 0)
                plan = region_mod.plan_region(e, region, closure)
                words = plan.payload_gather(e.bits).n_words
                row(f"fw_region_analytics/{name}/{op_name}-{tag}", us_reg,
                    f"full_us={us_full:.1f} speedup={us_full / us_reg:.2f}x "
                    f"words={words}/{e.payload.size} window=10%")


def fw_store_analytics():
    """Hot-cache store-backed fused queries vs cold (storeless) queries.

    Same field, same op set, same stage: the cold program unpacks the
    payload and recorrelates on *every* call; the hot program is seeded
    from the field's resident :class:`~repro.store.MaterializedStage` —
    the reconstruction happened once, at materialization — so each call
    pays only the op postludes.  Both sides run through warmed jit caches,
    so the speedup isolates exactly what residency saves: the per-call
    stage reconstruction.  Stage ③ is the serving sweet spot (the cached
    intermediate replaces unpack + the whole recorrelation pass) and the
    one the CI gate pins at >= 2x.
    """
    from repro.analytics import BatchedAnalytics, query
    from repro.store import FieldStore

    dims = dataset_dims("Ocean", SCALE)
    data = jnp.asarray(synth_field("Ocean", 0, dims))
    # two dashboard shapes: a stats-only set (light flat-reduction
    # postludes, so residency saves nearly the whole call) and the heavier
    # stencil set — the gate takes each scheme's best, the rows show both
    for name in ("hszp_nd", "hszx_nd"):
        comp = by_name(name)
        e = comp.encode(comp.compress(data, rel_eb=1e-2))
        for ops in (("mean", "std"), ("mean", "std", "laplacian")):
            for stage, tag in ((Stage.Q, "q"), (Stage.F, "f")):
                eng = BatchedAnalytics()
                store = FieldStore()
                store.put("bench/ocean0", e)
                # time .values (a pytree) so block_until_ready really blocks
                us_cold = best_of(lambda s=stage, o=ops: query(
                    [e], o, stage=s, engine=eng).values)
                # the first store-backed call materializes (the one
                # reconstruction of the field's lifetime) and compiles the
                # seeded program
                query(["bench/ocean0"], ops, stage=stage, engine=eng,
                      store=store)
                us_hot = best_of(lambda s=stage, o=ops: query(
                    ["bench/ocean0"], o, stage=s, engine=eng,
                    store=store).values)
                speedup = us_cold / us_hot
                row_name = f"fw_store_analytics/{name}/{'+'.join(ops)}-{tag}"
                row(row_name, us_hot,
                    f"cold_us={us_cold:.1f} speedup={speedup:.2f}x "
                    f"hits={store.stats.hits} cached_MB="
                    f"{store.cache_bytes_in_use / 1e6:.1f}")
                STORE_JSON.append({"name": row_name, "scheme": name,
                                   "stage": stage.name,
                                   "us": round(us_hot, 1),
                                   "cold_us": round(us_cold, 1),
                                   "speedup": round(speedup, 3)})


def fw_stream_analytics():
    """Streaming ingest: incremental append+query vs re-encode-from-scratch.

    The incremental path appends ONE compressed slab and merges its integer
    summary into the stream's resident :class:`~repro.stream.TemporalSummary`
    (``repro.stream``, DESIGN.md §9), then answers the temporal op set from
    the merged summary; the baseline re-encodes the *whole* concatenated
    history as a fresh field on every step and recomputes from scratch —
    which is what a store without streaming support would have to do.  Both
    sides run through warmed jit caches and identical op machinery, so the
    speedup isolates exactly what incrementality saves: re-compressing and
    re-reconstructing the history.  Results are bit-identical by the
    integer-merge contract (pinned in ``tests/test_stream.py``); the CI
    gate holds the per-scheme speedup at >= 2x.
    """
    from repro.analytics import BatchedAnalytics, query
    from repro.stream import StreamFieldStore, TemporalField

    # like the other fw serving benches this pins the streaming regime (a
    # steady feed of moderate timestep tiles) instead of scaling the tile
    # with --scale; per-op throughput vs size is covered by fig3-12
    # the baseline history length matches the stream's slab count midway
    # through the incremental measurement (it keeps growing; the
    # incremental cost does not)
    k, n_prefill, n_baseline, tile = 3, 4, 8, (96, 96)
    ops = ("tmean", "tstd", "tdelta")
    # feed sizing: prefill + 2 warm appends + best_of's 1 + max(5, REPS)
    # timed appends (so high --reps never exhausts the stream), + slack
    n_feed = n_prefill + 3 + max(5, REPS) + 2
    slab_data = [np.stack([synth_field("Ocean", 0, tile, seed=i * k + t)
                           for t in range(k)]).astype(np.float32)
                 for i in range(n_feed)]
    for name in COMPRESSORS:
        comp = by_name(name)
        eng = BatchedAnalytics()
        store = StreamFieldStore(engine=eng)
        tf = TemporalField(comp, rel_eb=1e-2, bits=16)
        store.put_temporal("stream/ocean", tf)
        feed = iter(slab_data)
        for _ in range(n_prefill):
            store.append("stream/ocean", next(feed))
        # warm: one cold query (summary build) + one steady append cycle
        query(["stream/ocean"], list(ops), store=store, engine=eng)
        store.append("stream/ocean", next(feed))
        query(["stream/ocean"], list(ops), store=store, engine=eng)

        def inc_step():
            store.append("stream/ocean", next(feed))
            return query(["stream/ocean"], list(ops), store=store,
                         engine=eng).values

        us_inc = best_of(inc_step, k=5)

        history = np.concatenate(slab_data[:n_baseline], axis=0)
        eng2 = BatchedAnalytics()

        def reencode_step():
            fresh = TemporalField(comp, eps=tf.eps, bits=16)
            fresh.append(history)           # re-encode the whole history
            return query([fresh], list(ops), engine=eng2).values

        us_re = best_of(reencode_step, k=5)
        speedup = us_re / us_inc
        row_name = f"fw_stream_analytics/{name}/append+query"
        row(row_name, us_inc,
            f"reencode_us={us_re:.1f} speedup={speedup:.2f}x "
            f"slabs={tf.n_slabs} steps={tf.n_steps} "
            f"merges={store.incremental_merges}")
        STREAM_JSON.append({"name": row_name, "scheme": name,
                            "us": round(us_inc, 1),
                            "reencode_us": round(us_re, 1),
                            "speedup": round(speedup, 3)})


def fw_shard_analytics():
    """Sharded vs single-device analytics: per-shard bytes touched + wall.

    The sharded store's tentpole claim is I/O locality, not CPU speed: a
    region query over a block-striped field gathers payload words only from
    the shards whose stripes the region closure covers, so the *max
    per-shard* bytes touched — the quantity that bounds a real multi-host
    deployment's per-node decode work — drops well below the single-device
    gather.  Two row kinds per scheme:

    * ``region`` — one region op set (mean at ③, window = 1/4 of the rows,
      away from the origin) through :meth:`ShardPrograms.region_compute`
      vs the jitted single-device op.  Bytes come from the *logical*
      8-shard :class:`~repro.shard.BlockPlacement` (the CI placement
      basis, independent of how many XLA devices this process has);
      ``bytes_ratio = max_shard_bytes / single_bytes`` is the gated value
      (< 0.5 on every scheme — the region covers >= 2 stripe units of
      every scheme's striping, so no shard owns more than half its words).
    * ``temporal`` — a cold full-window summary rebuild through the
      sharded banded path vs the single-device ``_cold_summary`` route;
      ``max_band_frac`` reports the largest fraction of window rows any
      one shard reconstructs under the logical placement.

    Wall times use a mesh over however many devices exist (1 on a plain
    CPU run, 8 under ``--xla_force_host_platform_device_count=8``) and are
    informational on CPU — shard_map over virtual devices serializes the
    per-shard work.  Results are bit-identical by construction
    (``tests/test_shard.py``), so the rows compare cost only; the geometry
    is pinned (like the other fw serving benches) so the byte accounting
    is the same at every ``--scale``.
    """
    from repro.analytics.engine import BatchedAnalytics
    from repro.core import oplib
    from repro.launch.mesh import make_analytics_mesh
    from repro.shard import (BlockPlacement, ShardPrograms, ShardedFieldStore,
                             spatial_bands)
    from repro.stream import StreamFieldStore, TemporalField
    from repro.stream.query import _cold_summary

    n_logical = 8
    mesh = make_analytics_mesh(min(n_logical, len(jax.devices())))
    n_mesh = mesh.devices.size

    tile = (256, 192)                     # 16 block-rows for the nd schemes
    data = jnp.asarray(synth_field("Ocean", 0, tile))
    region = ((tile[0] // 4, tile[0] // 2), (0, tile[1]))  # 1/4 of the rows
    stage = Stage.Q
    for name in COMPRESSORS:
        comp = by_name(name)
        e = comp.encode(comp.compress(data, rel_eb=1e-2))
        cl = oplib.set_closure(("mean",), e.scheme, stage, 0)
        plan = region_mod.plan_region(
            e, region_mod.normalize_region(region, e.shape), cl)
        acct = BlockPlacement.of(e, n_logical).payload_bytes(plan, e.bits)
        ratio = acct["max_shard_bytes"] / max(acct["single_bytes"], 1)

        progs = ShardPrograms(mesh)
        pm = BlockPlacement.of(e, n_mesh)
        stripes = [progs.shard_payload(e, pm)]
        us_sh = best_of(lambda: progs.region_compute(
            e, ("mean",), stage, region=region, placements=[pm],
            stripes=stripes)["mean"])
        us_single = best_of(
            jax.jit(lambda enc: H.mean(enc, stage, region=region)), e)
        row_name = f"fw_shard_analytics/{name}/region-mean-q"
        row(row_name, us_sh,
            f"single_us={us_single:.1f} "
            f"max_shard_bytes={acct['max_shard_bytes']} "
            f"single_bytes={acct['single_bytes']} bytes_ratio={ratio:.3f} "
            f"participants={len(acct['participants'])}/{n_logical}")
        SHARD_JSON.append({
            "name": row_name, "scheme": name, "kind": "region",
            "us_sharded": round(us_sh, 1), "us_single": round(us_single, 1),
            "max_shard_bytes": int(acct["max_shard_bytes"]),
            "single_bytes": int(acct["single_bytes"]),
            "bytes_ratio": round(ratio, 4),
            "participants": len(acct["participants"]),
            "n_shards": n_logical})

    k, n_slabs, ttile = 3, 4, (96, 96)
    slab_data = [np.stack([synth_field("Ocean", 0, ttile, seed=i * k + t)
                           for t in range(k)]).astype(np.float32)
                 for i in range(n_slabs)]
    for name in COMPRESSORS:
        comp = by_name(name)
        ref = StreamFieldStore(engine=BatchedAnalytics())
        sh = ShardedFieldStore(mesh, engine=BatchedAnalytics())
        ref.put_temporal("shard/stream", TemporalField(comp, rel_eb=1e-2))
        sh.put_temporal("shard/stream", TemporalField(comp, rel_eb=1e-2))
        for s in slab_data:
            ref.append("shard/stream", jnp.asarray(s))
            sh.append("shard/stream", jnp.asarray(s))

        def cold(store):
            store.invalidate("shard/stream")
            return store.temporal_summary("shard/stream")

        us_single = best_of(cold, ref, k=5)
        us_sh = best_of(cold, sh, k=5)
        slab0 = sh.get("shard/stream").slabs[0]
        p8 = BlockPlacement.of(slab0, n_logical, axis=1)
        per = np.zeros(n_logical)
        for owner, _, _, breg in spatial_bands(slab0, p8):
            per[owner] += breg[0][1] - breg[0][0]
        frac = float(per.max()) / slab0.shape[1]
        row_name = f"fw_shard_analytics/{name}/temporal-summary"
        row(row_name, us_sh,
            f"single_us={us_single:.1f} slabs={n_slabs} "
            f"max_band_frac={frac:.3f}")
        SHARD_JSON.append({
            "name": row_name, "scheme": name, "kind": "temporal",
            "us_sharded": round(us_sh, 1), "us_single": round(us_single, 1),
            "max_band_frac": round(frac, 4), "n_shards": n_logical})


#: jaxpr primitives that are elementwise or pure layout — free under the
#: same fusion assumption ``hlo_analysis.ELEMENTWISE`` makes for HLO ops.
_FREE_PRIMS = frozenset({
    "add", "sub", "mul", "div", "rem", "neg", "sign", "abs", "max", "min",
    "and", "or", "xor", "not", "shift_left", "shift_right_logical",
    "shift_right_arithmetic", "eq", "ne", "lt", "le", "gt", "ge",
    "select_n", "convert_element_type", "integer_pow", "exp", "log",
    "sqrt", "rsqrt", "floor", "ceil", "round", "stop_gradient", "copy",
    "reshape", "squeeze", "broadcast_in_dim", "slice", "concatenate",
    "pad", "iota", "transpose",
})


def _jaxpr_bytes(jaxpr) -> int:
    """HBM-bytes proxy of a (native-lowering) jaxpr: summed output bytes of
    every non-elementwise equation, recursing through call wrappers.

    The counterpart of ``hlo_analysis.analyze`` for programs containing
    ``pallas_call`` equations, which cannot be measured from compiled HLO
    on CPU: interpret mode emulates the grid with per-step dynamic-slice /
    full-array dynamic-update-slice pairs whose HLO bytes are pure
    emulation artifact (24-34x the real kernel I/O).  A pallas_call counts
    its *outputs* only — its operands are the outputs of counted producers
    (the payload word gather, halo gathers) or program arguments, exactly
    as HLO op outputs chain in the proxy.
    """
    total = 0
    for eqn in jaxpr.eqns:
        name = eqn.primitive.name
        if name == "pallas_call":
            total += sum(int(np.prod(v.aval.shape)) * v.aval.dtype.itemsize
                         for v in eqn.outvars)
            continue
        subs = []
        for v in eqn.params.values():
            for s in (v if isinstance(v, (tuple, list)) else (v,)):
                inner = getattr(s, "jaxpr", s)
                if hasattr(inner, "eqns"):
                    subs.append(inner)
        if subs:
            total += sum(_jaxpr_bytes(s) for s in subs)
            continue
        if name in _FREE_PRIMS:
            continue
        total += sum(int(np.prod(v.aval.shape)) * v.aval.dtype.itemsize
                     for v in eqn.outvars)
    return total


def fw_kernel_analytics():
    """Fused Pallas decode+op kernels vs the XLA lowering, per scheme family.

    One covered cell per op family (derivative at ③, laplacian at the
    family's covered stage), Ocean 2-D, both nd schemes, *Encoded*
    containers passed as real jit arguments (a closed-over container
    constant-folds the whole program away).  Two measurements per cell:

    * wall time of the jitted single-op program, fused backend vs
      ``REPRO_KERNELS=off`` — informational only on CPU, where the kernels
      run under interpret-mode emulation;
    * the HBM-bytes proxy — the tentpole's gated claim.  The XLA side
      comes from ``hlo_analysis.analyze`` on the compiled program; the
      fused side from :func:`_jaxpr_bytes` on the ``native``-mode jaxpr
      (traced, never compiled — CPU has no native Pallas lowering), the
      same output-bytes-of-non-elementwise-ops accounting.  The fused
      program reads gathered payload words and writes stencil planes; the
      XLA program materializes the unpacked residuals and the full-field
      integer recorrelation intermediate in between, so the ratio must
      clear the CI gate (< 0.9) for *both* families.

    Results are bit-identical by construction
    (``tests/test_fused_kernels.py``), so the rows compare cost only.
    """
    from repro.kernels import ops as kops
    from repro.launch import hlo_analysis

    dims = dataset_dims("Ocean", SCALE)[:2]
    data = jnp.asarray(synth_field("Ocean", 0, dims))
    cells = [("derivative", Stage.Q,
              lambda e: H.derivative(e, Stage.Q, 0)),
             ("laplacian", Stage.P,
              lambda e: H.laplacian(e, Stage.P))]
    for name in ("hszp_nd", "hszx_nd"):
        comp = by_name(name)
        enc = comp.encode(comp.compress(data, rel_eb=1e-3))
        for op, stage, call in cells:
            us_fused = best_of(jax.jit(call), enc)
            with kops.override_mode("native"):
                bytes_fused = _jaxpr_bytes(jax.make_jaxpr(call)(enc).jaxpr)
            with kops.override_mode("off"):
                xla_fn = jax.jit(call)
                us_xla = best_of(xla_fn, enc)
                bytes_xla = hlo_analysis.analyze(
                    xla_fn.lower(enc).compile().as_text())["bytes_proxy"]
            row_name = f"fw_kernel_analytics/{name}/{op}-{stage.name.lower()}"
            row(row_name, us_fused,
                f"xla_us={us_xla:.1f} bytes_fused={bytes_fused:.3g} "
                f"bytes_xla={bytes_xla:.3g} "
                f"bytes_ratio={bytes_fused / max(bytes_xla, 1):.3f}")
            KERNEL_JSON.append({
                "name": row_name, "scheme": name, "op": op,
                "stage": stage.name, "us_fused": round(us_fused, 1),
                "us_xla": round(us_xla, 1),
                "bytes_fused": round(bytes_fused),
                "bytes_xla": round(bytes_xla),
                "bytes_ratio": round(bytes_fused / max(bytes_xla, 1), 4)})


def fw_collective_bytes():
    """Wire bytes of the gradient all-reduce: f32 baseline vs hom-int16.

    Static accounting (per the ring cost model, 2x payload); the dry-run
    HLO confirms the wire dtype (EXPERIMENTS.md §Perf).
    """
    from repro.comm import bit_budget
    n_params = 4_000_000_000
    for world in (16, 256, 512):
        f32 = 2 * n_params * 4
        i16 = 2 * n_params * 2
        bits = bit_budget(world)
        row(f"fw_collective/world{world}", 0.0,
            f"f32_GB={f32/1e9:.1f} hom16_GB={i16/1e9:.1f} budget_bits={bits}")


BENCHES = [fig2_compression_ratio, fig34_decompression, fig58_statistics,
           fig910_differentiation, fig1112_multivariate, table4_breakdown,
           table5_op_errors, fw_batched_analytics, fw_fused_analytics,
           fw_expr_analytics, fw_region_analytics, fw_store_analytics,
           fw_stream_analytics, fw_kernel_analytics, fw_shard_analytics,
           fw_checkpoint, fw_collective_bytes]


def select_benches(benches, filter_spec: str | None, only: str | None):
    """Row families selected by ``--filter`` (comma-separated name prefixes)
    and ``--only`` (substring, kept for compatibility)."""
    out = list(benches)
    if filter_spec:
        prefixes = [p for p in filter_spec.split(",") if p]
        out = [b for b in out
               if any(b.__name__.startswith(p) for p in prefixes)]
        if not out:
            known = ", ".join(b.__name__ for b in benches)
            raise SystemExit(
                f"--filter {filter_spec!r} matches no row family; "
                f"families: {known}")
    if only:
        out = [b for b in out if only in b.__name__]
    return out


def main() -> None:
    global SCALE, REPS
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=int, default=8)
    ap.add_argument("--reps", type=int, default=3)
    ap.add_argument("--only", default=None)
    ap.add_argument("--filter", default=None, metavar="PREFIX[,PREFIX...]",
                    help="run only row families whose name starts with a "
                         "given prefix (e.g. fw_store or fig2,fw_)")
    ap.add_argument("--json-dir", default=None, metavar="DIR",
                    help="write every machine-readable row family into DIR "
                         "under its canonical name (BENCH_fused.json, "
                         "BENCH_expr.json, BENCH_store.json, "
                         "BENCH_stream.json, BENCH_kernel.json, "
                         "BENCH_shard.json) — the one flag the CI gates "
                         "consume; families not selected by --filter come "
                         "out as empty lists")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="deprecated alias: write only the "
                         "fw_fused_analytics rows to PATH (use --json-dir)")
    ap.add_argument("--json-expr", default=None, metavar="PATH",
                    help="deprecated alias: write only the "
                         "fw_expr_analytics rows to PATH (use --json-dir)")
    ap.add_argument("--json-store", default=None, metavar="PATH",
                    help="deprecated alias: write only the "
                         "fw_store_analytics rows to PATH (use --json-dir)")
    ap.add_argument("--json-stream", default=None, metavar="PATH",
                    help="deprecated alias: write only the "
                         "fw_stream_analytics rows to PATH (use --json-dir)")
    ap.add_argument("--json-kernel", default=None, metavar="PATH",
                    help="deprecated alias: write only the "
                         "fw_kernel_analytics rows to PATH (use --json-dir)")
    args = ap.parse_args()
    SCALE, REPS = args.scale, args.reps
    print("name,us_per_call,derived")
    for bench in select_benches(BENCHES, args.filter, args.only):
        t0 = time.time()
        bench()
        print(f"# {bench.__name__} done in {time.time()-t0:.1f}s", flush=True)
        while ROWS:
            name, us, derived = ROWS.pop(0)
            print(f"{name},{us:.1f},{derived}")
    if args.json_dir is not None:
        os.makedirs(args.json_dir, exist_ok=True)
        for fname, rows_json in JSON_FILES:
            with open(os.path.join(args.json_dir, fname), "w") as f:
                json.dump(rows_json, f, indent=2)
    aliases = (("--json", args.json, FUSED_JSON),
               ("--json-expr", args.json_expr, EXPR_JSON),
               ("--json-store", args.json_store, STORE_JSON),
               ("--json-stream", args.json_stream, STREAM_JSON),
               ("--json-kernel", args.json_kernel, KERNEL_JSON))
    for flag, path, rows_json in aliases:
        if path is None:
            continue
        warnings.warn(f"{flag} is a deprecated alias; use --json-dir DIR "
                      "(writes every BENCH_*.json)", DeprecationWarning,
                      stacklevel=2)
        with open(path, "w") as f:
            json.dump(rows_json, f, indent=2)


if __name__ == "__main__":
    main()
