"""Batched serving with HSZ stage-③ (int8) KV-cache residency.

    PYTHONPATH=src python examples/serve_batched.py
"""
import dataclasses
import time

import numpy as np
import jax

from repro.configs import ARCHS, reduced
from repro.models import get_model
from repro.serve import Engine, Request


def main():
    base = reduced(ARCHS["qwen3-4b"])
    rng = np.random.default_rng(0)
    for kv_quant in (False, True):
        cfg = dataclasses.replace(base, kv_quant=kv_quant)
        model = get_model(cfg)
        params, _ = model.init(jax.random.PRNGKey(0))
        eng = Engine(model, params, slots=4, max_len=96)
        for i in range(8):
            eng.add_request(Request(
                uid=i, prompt=rng.integers(1, cfg.vocab, 6).astype(np.int32),
                max_new_tokens=12))
        t0 = time.time()
        done = eng.run_until_drained()
        dt = time.time() - t0
        cache_bytes = sum(np.asarray(l).nbytes
                          for l in jax.tree.leaves(eng.cache))
        toks = sum(len(r.out_tokens) for r in done)
        print(f"kv_quant={str(kv_quant):5s}: {len(done)} requests, {toks} tokens "
              f"in {dt:.1f}s | KV cache bytes/slot: {cache_bytes//4:,}")
        sample = done[0]
        print(f"  request {sample.uid}: prompt {list(sample.prompt)} -> "
              f"{sample.out_tokens}")


if __name__ == "__main__":
    main()
