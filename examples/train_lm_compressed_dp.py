"""End-to-end training driver: a small LM for a few hundred steps with
HSZ-integrated infrastructure — compressed checkpoints with homomorphic
validation, stage-① gradient telemetry, simulated failure + restart.

    PYTHONPATH=src python examples/train_lm_compressed_dp.py \
        [--steps 200] [--fail-at 120] [--ckpt-dir /tmp/hsz_ckpt]
"""
import argparse
import dataclasses
import os
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.comm import stage1_stats
from repro.configs import ARCHS, reduced
from repro.data.tokens import TokenPipeline, TokenPipelineConfig
from repro.models import get_model
from repro.train import checkpoint as ckpt
from repro.train import optimizer as opt_lib
from repro.train import train_step as ts_lib


def build(seq_len, batch):
    cfg = dataclasses.replace(
        reduced(ARCHS["smollm-360m"]), d_model=128, n_layers=4, n_heads=8,
        n_kv=4, head_dim=16, d_ff=384, vocab=2048, remat="none")
    model = get_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    n_params = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
    opt_cfg = opt_lib.AdamWConfig(lr=1e-3, warmup_steps=20, total_steps=400)
    # grads come back through the value_and_grad path; the homomorphic
    # compressed all-reduce engages on multi-device meshes (see dry-run)
    step = jax.jit(ts_lib.make_train_step(model, opt_cfg),
                   donate_argnums=(0,))
    pipe = TokenPipeline(TokenPipelineConfig(vocab=cfg.vocab, seq_len=seq_len,
                                             global_batch=batch))
    return cfg, model, step, ts_lib.init_state(params), pipe, n_params


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--fail-at", type=int, default=120,
                    help="simulate a node failure at this step (0 = off)")
    ap.add_argument("--ckpt-dir", default="/tmp/hsz_ckpt")
    args = ap.parse_args()

    cfg, model, step, state, pipe, n_params = build(args.seq_len, args.batch)
    print(f"model: {n_params/1e6:.1f}M params | tokens/step: "
          f"{args.batch * args.seq_len}")
    os.makedirs(args.ckpt_dir, exist_ok=True)

    # resume if a checkpoint exists (restart-after-failure path)
    last = ckpt.latest_step(args.ckpt_dir)
    if last is not None:
        restored = ckpt.restore(args.ckpt_dir, last,
                                state._asdict() | {"data": pipe.state_dict()})
        pipe.load_state_dict(restored.pop("data"))
        state = ts_lib.TrainState(**restored)
        print(f"resumed from checkpoint step {last}")

    t0 = time.time()
    failed = False
    while int(state.step) < args.steps:
        batch = {k: jnp.asarray(v) for k, v in next(pipe).items()}
        state, metrics = step(state, batch)
        s = int(state.step)
        if s % 20 == 0 or s == 1:
            # stage-① homomorphic telemetry on the CURRENT params (cheap)
            stats = stage1_stats(state.params)
            print(f"step {s:4d} loss {float(metrics['loss']):.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f} "
                  f"| hom-telemetry: param_rms {float(stats['rms']):.4f} "
                  f"({time.time()-t0:.0f}s)")
        if s % args.ckpt_every == 0:
            ckpt.save(args.ckpt_dir, s,
                      state._asdict() | {"data": pipe.state_dict()},
                      mode="hsz", rel_eb=1e-6, keep=3)
        if args.fail_at and s == args.fail_at and not failed:
            failed = True
            print(f"!! simulated failure at step {s} — restarting from latest "
                  f"checkpoint")
            cfg, model, step, state, pipe, _ = build(args.seq_len, args.batch)
            last = ckpt.latest_step(args.ckpt_dir)
            restored = ckpt.restore(args.ckpt_dir, last,
                                    state._asdict() | {"data": pipe.state_dict()})
            pipe.load_state_dict(restored.pop("data"))
            state = ts_lib.TrainState(**restored)

    print(f"done: {args.steps} steps in {time.time()-t0:.0f}s; final loss "
          f"{float(metrics['loss']):.4f}")


if __name__ == "__main__":
    main()
