"""Derived operators: vorticity from (u, v) as one expression query.

Builds a rigid-rotation velocity field (closed-form vorticity == +2),
registers u and v in a FieldStore, and computes

    vorticity = dv/dx - du/dy

as ONE expression query (DESIGN.md §10): one compiled program, one stage
reconstruction per component, store-seeded on the second run.  Compares
against the naive spelling (two single-derivative queries composed on the
host) for both correctness and dispatch count, then shows a couple more
derived quantities riding the same store.

    PYTHONPATH=src python examples/derived_operators.py [--n 192]
"""
import argparse
import time

import numpy as np
import jax.numpy as jnp

from repro.analytics import query
from repro.analytics.engine import BatchedAnalytics
from repro.core import by_name, expr
from repro.store import FieldStore


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=192)
    args = ap.parse_args()
    n = args.n

    # rigid rotation (u, v) = (-y, x): vorticity dv/dx - du/dy == 2 exactly
    i = np.arange(n, dtype=np.float32)[:, None] + np.zeros((n, n), np.float32)
    j = np.arange(n, dtype=np.float32)[None, :] + np.zeros((n, n), np.float32)
    comp = by_name("hszp_nd")
    store = FieldStore(cache_bytes=256 << 20)
    store.put("u", comp.compress(jnp.asarray(-j), abs_eb=0.25))
    store.put("v", comp.compress(jnp.asarray(i), abs_eb=0.25))
    engine = BatchedAnalytics()

    vort = expr.sub(expr.derivative("v", axis=0), expr.derivative("u", axis=1))
    res = query(exprs=[vort], store=store, engine=engine)   # cold: materializes
    t0 = time.perf_counter()
    res = query(exprs=[vort], store=store, engine=engine)   # warm: seeded
    dt = time.perf_counter() - t0
    w = np.asarray(res.values[0])
    print(f"vorticity: shape {w.shape}, mean {w.mean():+.6f} (exact +2), "
          f"stage {res.stages[0].name}, {res.n_dispatches} dispatch(es), "
          f"store hits {res.store_hits}, {dt * 1e3:.2f} ms warm")

    # naive composition: one query per derivative, combined on the host
    naive = query(exprs=[expr.op("derivative", "v", axis=0)],
                  store=store, engine=engine)
    naive2 = query(exprs=[expr.op("derivative", "u", axis=1)],
                   store=store, engine=engine)
    w_naive = np.asarray(naive.values[0]) - np.asarray(naive2.values[0])
    print(f"naive compose: {naive.n_dispatches + naive2.n_dispatches} "
          f"dispatches, max |delta| vs expression "
          f"{np.abs(w - w_naive).max():.2e}")

    # several derived quantities in one program: leaves decode once each
    batch = query(exprs=[vort,
                         expr.laplacian("u") + expr.laplacian("v"),
                         2.0 * expr.mean("u") - expr.std("v")],
                  store=store, engine=engine)
    print(f"3 derived roots over 2 leaves: {batch.n_dispatches} dispatch(es), "
          f"stages {[s.name for s in batch.stages]}")


if __name__ == "__main__":
    main()
