"""The paper's end-to-end pipeline (Fig. 1) over its five benchmark datasets.

Compresses synthetic analogues of Ocean/Miranda/Hurricane/NYX/JHTDB, then
runs all six analytical operations at their cheapest supported stage and
reports ratio / throughput / error vs full decompression.

    PYTHONPATH=src python examples/homomorphic_analytics.py [--scale 16]
"""
import argparse
import time

import numpy as np
import jax

from repro.analytics import query
from repro.core import Stage, by_name, homomorphic as H
from repro.core import region as region_mod
from repro.data.scientific import DATASETS, ScientificStore, dataset_dims
from repro.serve import AnalyticsFrontend, AnalyticsRequest
from repro.store import FieldStore


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=int, default=16)
    ap.add_argument("--rel-eb", type=float, default=1e-3)
    args = ap.parse_args()

    print(f"{'dataset':10s} {'dims':>18s} {'comp':8s} {'ratio':>6s} "
          f"{'mean(M/P)':>10s} {'std(P)':>8s} {'deriv(Q)':>9s} {'max err':>9s}")
    for ds in DATASETS:
        dims = dataset_dims(ds, args.scale)
        for comp_name in ("hszp_nd", "hszx_nd"):
            store = ScientificStore(compressor_name=comp_name,
                                    scale=args.scale, rel_eb=args.rel_eb)
            c = store.get(ds, 0).open()
            comp = by_name(comp_name)
            ratio = float(comp.compression_ratio(c))
            raw = np.asarray(store.raw(ds, 0))

            stage1 = Stage.M if c.scheme.is_blockmean else Stage.P
            t0 = time.perf_counter()
            mu = float(H.mean(c, stage1))
            t_mu = time.perf_counter() - t0
            sd = float(H.std(c, Stage.P))
            t0 = time.perf_counter()
            d0 = np.asarray(H.derivative(c, Stage.Q, 0))
            t_d = time.perf_counter() - t0

            ref0 = np.asarray(H.derivative(c, Stage.F, 0))
            err = max(abs(mu - raw.mean()),
                      abs(sd - raw.std(ddof=1)),
                      float(np.abs(d0 - ref0).max()))
            print(f"{ds:10s} {str(dims):>18s} {comp_name:8s} {ratio:6.2f} "
                  f"{t_mu*1e3:9.2f}ms {sd:8.4f} {t_d*1e3:8.2f}ms {err:9.2e}")

    print("\nMulti-operation reuse (paper §VI-C.6): one lowered stage-③ "
          "reconstruction feeds gradient + curl on NYX velocity:")
    store = ScientificStore(compressor_name="hszp_nd", scale=args.scale)
    comps = [store.get("NYX", i).open() for i in range(3)]
    t0 = time.perf_counter()
    grads = [H.gradient(cc, Stage.Q) for cc in comps]  # 9 derivatives, 3 decodes
    curl = H.curl(comps, Stage.Q)
    jax.block_until_ready((grads, curl))
    print(f"3 gradients + 3-component curl at stage Q: "
          f"{(time.perf_counter()-t0)*1e3:.1f} ms")

    print("\nBatched analytics (repro.analytics): all Hurricane variables, "
          "one vmapped dispatch, stage planned automatically:")
    store = ScientificStore(compressor_name="hszx_nd", scale=args.scale)
    n_vars = DATASETS["Hurricane"][0]
    fields = [store.get("Hurricane", i).open() for i in range(n_vars)]
    res = query(fields, "mean", stage="auto")        # warm the jit cache
    t0 = time.perf_counter()
    res = query(fields, "mean", stage="auto")
    jax.block_until_ready(res.values)
    t_batch = time.perf_counter() - t0
    print(f"  mean over {n_vars} variables at stage {res.stages[0].name}: "
          f"{t_batch*1e3:.2f} ms ({res.n_batches} dispatch)")

    print("\nFused multi-op dashboard query: mean + std + laplacian over "
          "every variable from ONE stage reconstruction per layout group "
          "(bit-packed fields: sequential ops re-decode, the fused set "
          "decodes once):")
    comp_x = by_name("hszx_nd")
    bits = max(comp_x.max_bits(c) for c in fields)
    enc = [comp_x.encode(c, bits=bits) for c in fields]
    dashboard = ["mean", "std", "laplacian"]
    fused = query(enc, dashboard)                    # warm both jit caches
    for op in dashboard:
        query(enc, op, stage=fused.stages[0][op])

    def best_of(fn, k=3):                            # min-of-k: robust timing
        best = float("inf")
        for _ in range(k):
            t0 = time.perf_counter()
            jax.block_until_ready(fn())
            best = min(best, time.perf_counter() - t0)
        return best

    t_fused = best_of(lambda: [v for d in query(enc, dashboard).values
                               for v in d.values()])
    t_seq = best_of(lambda: [v for op in dashboard
                             for v in query(enc, op,
                                            stage=fused.stages[0][op]).values])
    stage_names = {op: s.name for op, s in fused.stages[0].items()}
    print(f"  {len(dashboard)} ops x {n_vars} variables at stages "
          f"{stage_names}: fused {t_fused*1e3:.2f} ms "
          f"({fused.n_dispatches} dispatch) vs sequential {t_seq*1e3:.2f} ms "
          f"({len(dashboard)} dispatches); "
          f"var0 mean={float(fused.values[0]['mean']):.4f} "
          f"std={float(fused.values[0]['std']):.4f}")

    print("\nBlock-sparse region queries (windowed/ROI workload): a ~10% "
          "window decodes only its covering blocks:")
    c = fields[0]
    region = tuple((s // 4, s // 4 + max(4, int(s * 0.32))) for s in c.shape)
    e = by_name("hszx_nd").encode(c)
    plan = region_mod.plan_region(e, region, "cover")
    words = plan.payload_gather(e.bits).n_words
    full_fn = jax.jit(lambda enc: H.mean(enc, Stage.P))
    reg_fn = jax.jit(lambda enc: H.mean(enc, Stage.P, region=region))
    jax.block_until_ready(full_fn(e)), jax.block_until_ready(reg_fn(e))
    t0 = time.perf_counter()
    jax.block_until_ready(full_fn(e))
    t_full = time.perf_counter() - t0
    t0 = time.perf_counter()
    mu_win = float(reg_fn(e))
    t_reg = time.perf_counter() - t0
    print(f"  window {region}: mean={mu_win:.4f} in {t_reg*1e3:.2f} ms vs "
          f"{t_full*1e3:.2f} ms full-field ({words}/{e.payload.size} payload "
          f"words gathered)")

    print("\nServing front-end (second request type next to token "
          "generation):")
    fe = AnalyticsFrontend()
    for i, c in enumerate(fields):
        fe.add_request(AnalyticsRequest(uid=i, fields=c, op="std"))
    fe.add_request(AnalyticsRequest(uid=100, fields=fields[0], op="laplacian"))
    fe.add_request(AnalyticsRequest(uid=101, fields=fields[0], op="std",
                                    region=region))
    fe.add_request(AnalyticsRequest(uid=102, fields=fields[0],
                                    op=["mean", "std", "laplacian"]))
    done = fe.run_until_drained()
    stds = [f"{float(r.result):.3f}" for r in done if r.op == "std" and r.region is None]
    win_std = next(float(r.result) for r in done if r.region is not None)
    multi = next(r for r in done if r.uid == 102)
    print(f"  {len(done)} requests drained "
          f"({fe.engine.cache_size} compiled programs); stds: {stds[:4]} ...; "
          f"window std: {win_std:.3f}; fused request: "
          f"mean={float(multi.result['mean']):.3f} "
          f"std={float(multi.result['std']):.3f} at one "
          f"stage-{multi.result_stage['mean'].name} reconstruction")

    print("\nStore-backed serving (repro.store): fields registered under "
          "string ids, one stage reconstruction per field *lifetime* — "
          "clients stop shipping arrays:")
    fstore = FieldStore(cache_bytes=256 << 20)
    for i, ec in enumerate(enc):
        fstore.put(f"hurricane/var{i}", ec)
    ids = [f"hurricane/var{i}" for i in range(len(enc))]
    # cold: the first store-backed query materializes (and the jit warms)
    res = query(ids, dashboard, stage=Stage.Q, store=fstore)
    t_cold = best_of(lambda: [v for d in query(enc, dashboard, stage=Stage.Q)
                              .values for v in d.values()])
    t_hot = best_of(lambda: [v for d in query(ids, dashboard, stage=Stage.Q,
                                              store=fstore).values
                             for v in d.values()])
    print(f"  {len(dashboard)} ops x {len(ids)} id-addressed fields: hot "
          f"cache {t_hot*1e3:.2f} ms vs {t_cold*1e3:.2f} ms storeless "
          f"({t_cold/t_hot:.1f}x); stats: {fstore.stats}, "
          f"{fstore.cache_bytes_in_use/1e6:.1f} MB resident")
    sfe = AnalyticsFrontend(store=fstore)
    sfe.add_request(AnalyticsRequest(uid=0, fields=ids[0], op=["mean", "std"]))
    sfe.add_request(AnalyticsRequest(uid=1, fields=ids[1], op=["mean", "std"]))
    done = sfe.run_until_drained()
    print(f"  2 id-addressed requests -> stage "
          f"{done[0].result_stage['mean'].name} (auto, flipped to the "
          f"resident stage), mean={float(done[0].result['mean']):.3f}")

    print("\nStreaming ingest (repro.stream): timestep batches append as "
          "compressed slabs; temporal ops merge per-slab integer summaries "
          "— only the NEW slab is ever reconstructed:")
    from repro.serve import AppendRequest
    from repro.stream import StreamFieldStore, TemporalField

    sstore = StreamFieldStore(cache_bytes=256 << 20)
    comp_p = by_name("hszp_nd")
    sstore.put_temporal("sim/temp", TemporalField(comp_p, rel_eb=1e-3))
    dims2 = dataset_dims("Ocean", args.scale)
    rng = np.random.default_rng(0)

    def timesteps(i, k=3):
        from repro.data.scientific import synth_field
        base = synth_field("Ocean", 0, dims2)
        t = np.arange(i * k, (i + 1) * k, dtype=np.float32)[:, None, None]
        return (base[None] * (1 + 0.01 * t)
                + rng.normal(0, 0.01, (k,) + base.shape)).astype(np.float32)

    sfe = AnalyticsFrontend(store=sstore)
    for i in range(4):
        sfe.add_request(AppendRequest(uid=i, field_id="sim/temp",
                                      data=timesteps(i)))
    sfe.add_request(AnalyticsRequest(uid=10, fields="sim/temp",
                                     op=["tmean", "tstd", "tdelta"]))
    done = {r.uid: r for r in sfe.run_until_drained()}
    tfield = sstore.get("sim/temp")
    # warm the incremental path (slab summarizer + merge compile once, then
    # every further append reuses them), then time one steady-state cycle
    sstore.append("sim/temp", timesteps(4))
    jax.block_until_ready(
        query(["sim/temp"], ["tmean", "tstd", "tdelta"], store=sstore).values)
    t0 = time.perf_counter()
    sstore.append("sim/temp", timesteps(5))
    hot = query(["sim/temp"], ["tmean", "tstd", "tdelta"], store=sstore)
    jax.block_until_ready(hot.values)
    t_step = time.perf_counter() - t0
    print(f"  {tfield.n_slabs} slabs / {tfield.n_steps} timesteps ingested; "
          f"steady-state append+query {t_step*1e3:.2f} ms "
          f"({sstore.incremental_merges} incremental merges, "
          f"{sstore.summary_rebuilds} rebuild); "
          f"tmean[0,0]={float(hot.values[0]['tmean'][0, 0]):.4f}, "
          f"tdelta max={float(np.abs(np.asarray(hot.values[0]['tdelta'])).max()):.4f}")


if __name__ == "__main__":
    main()
