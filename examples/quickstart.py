"""Quickstart: compress a scientific field, analyze it without decompressing.

    PYTHONPATH=src python examples/quickstart.py
"""
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import Stage, hszp_nd, hszx_nd, homomorphic as H


def main():
    # a smooth 2-D field with noise (think: sea-surface temperature)
    rng = np.random.default_rng(0)
    g = np.linspace(0, 4 * np.pi, 1200)
    field = (np.sin(g)[:, None] * np.cos(g / 2)[None, :] * 5
             + rng.normal(0, 0.05, (1200, 1200))).astype(np.float32)
    data = jnp.asarray(field)

    print("== compress (HSZx-nd: block-mean metadata -> stage-1 stats) ==")
    c = hszx_nd.compress(data, rel_eb=1e-3)
    print(f"error bound eps = {float(c.eps):.3e}")
    print(f"compression ratio = {float(hszx_nd.compression_ratio(c)):.2f}x")

    print("\n== mean at each decompression stage ==")
    for stage in (Stage.M, Stage.P, Stage.Q, Stage.F):
        fn = jax.jit(lambda cc, s=stage: H.mean(cc, s))
        val = float(fn(c)); jax.block_until_ready(val)
        t0 = time.perf_counter()
        for _ in range(5):
            jax.block_until_ready(fn(c))
        dt = (time.perf_counter() - t0) / 5
        print(f"stage {stage.name}: mean={val:+.6f}   {dt*1e3:7.2f} ms "
              f"({'metadata only!' if stage == Stage.M else ''})")
    print(f"numpy reference: {field.mean():+.6f}")

    print("\n== derivatives straight from quantized integers (HSZp-nd) ==")
    cp = hszp_nd.compress(data, rel_eb=1e-3)
    for stage in (Stage.P, Stage.Q, Stage.F):
        d0 = np.asarray(H.derivative(cp, stage, 0))
        ref = (field[2:, 1:-1] - field[:-2, 1:-1]) / 2
        print(f"stage {stage.name}: max|err| vs raw data = "
              f"{np.abs(d0 - ref).max():.2e} (eps={float(cp.eps):.2e})")


if __name__ == "__main__":
    main()
