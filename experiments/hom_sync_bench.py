import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# isolated gradient-synchronization lowering: f32 baseline vs HSZ homomorphic
# int16 all-reduce, on the production mesh.  (The fused train_step + hom path
# trips an XLA CPU-partitioner CHECK — hlo_instruction.cc "Invalid binary
# instruction opcode copy" — so the collective term is measured on the
# isolated sync step; see EXPERIMENTS.md §Perf.)
import json
import sys

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.comm import hom_collectives as hom
from repro.configs import ARCHS
from repro.launch import hlo_analysis
from repro.models import get_model
from repro.launch import mesh as mesh_lib


def main(arch="qwen3-4b", multi_pod=False):
    mesh = mesh_lib.make_production_mesh(multi_pod=multi_pod)
    dp_axes = ("pod", "data") if multi_pod else ("data",)
    world = 1
    for a in dp_axes:
        world *= mesh.shape[a]
    axis = dp_axes[0] if len(dp_axes) == 1 else dp_axes

    model = get_model(ARCHS[arch])
    params_sds, specs = model.init(None)
    # gradients live sharded like the params minus the data(FSDP) axis —
    # for the sync comparison we treat per-shard grads as manual inputs
    grads_sds = jax.tree.map(
        lambda l: jax.ShapeDtypeStruct(l.shape, jnp.float32), params_sds)

    results = {}
    for mode in ("f32", "hom16"):
        def body(grads, residual, _mode=mode):
            if _mode == "f32":
                summed = jax.tree.map(
                    lambda g: jax.lax.psum(g, axis) / world, grads)
                return summed, residual
            return hom.compressed_psum_tree(grads, residual, axis, world)

        f = jax.shard_map(
            body, mesh=mesh, in_specs=(P(), P()), out_specs=(P(), P()),
            axis_names=set(dp_axes), check_vma=False)
        lowered = jax.jit(f).lower(grads_sds, grads_sds)
        compiled = lowered.compile()
        rec = hlo_analysis.analyze(compiled.as_text())
        results[mode] = {
            "wire_GB": rec["wire_bytes_total"] / 1e9,
            "collectives": {k: {kk: round(vv, 2) if isinstance(vv, float) else vv
                                for kk, vv in v.items()}
                            for k, v in rec["collectives"].items()},
        }
        print(f"{arch} {('2x16x16' if multi_pod else '16x16')} {mode}: "
              f"wire {results[mode]['wire_GB']:.1f} GB — "
              f"{results[mode]['collectives']}")
    out = f"experiments/hom_sync_{arch}_{'2x16x16' if multi_pod else '16x16'}.json"
    with open(out, "w") as fh:
        json.dump(results, fh, indent=1)
    ratio = results["f32"]["wire_GB"] / max(results["hom16"]["wire_GB"], 1e-9)
    print(f"==> gradient-sync wire reduction: {ratio:.2f}x")


if __name__ == "__main__":
    arch = sys.argv[1] if len(sys.argv) > 1 else "qwen3-4b"
    mp = "--multi-pod" in sys.argv
    main(arch, mp)
